//! All-prefix-sums (scan) engines for binary associative operators.
//!
//! Implements the paper's computational core:
//!
//! * [`seq_scan`] / [`seq_scan_rev`] — the O(T) sequential baselines
//!   (thin wrappers over the in-place [`seq_scan_into`] /
//!   [`seq_scan_rev_into`], which the streaming sessions use to avoid
//!   per-append allocation),
//! * [`blelloch_scan`] — Algorithm 2 (up-sweep + down-sweep + final
//!   pass), generalized to arbitrary T, with optional multithreaded
//!   level execution (O(log T) span on P ≥ T processors),
//! * [`scan_rev`] — reversed all-prefix-sums (Definition 2): reverse the
//!   inputs, flip the operator, reverse the outputs (§III-B),
//! * [`chunked_scan`] — the two-level block-wise scan of §V-B used when
//!   cores ≪ T (and by the coordinator's temporal sharder).
//!
//! Operators are supplied through [`AssocOp`]; the element type is
//! generic so the same engine drives sum-product matrices, max-product
//! matrices, Bayesian-filter pairs and the path-based elements.
//!
//! [`checkpoint::CheckpointedScan`] persists the per-block summaries of
//! [`chunked_scan`] so a prefix scan can be *resumed* as observations
//! stream in — the substrate of `engine::Session`.

pub mod checkpoint;

pub use checkpoint::CheckpointedScan;

use crate::exec::parallel_for_chunks;

/// A binary associative operator with identity over elements `E`.
///
/// Associativity (`combine(combine(a,b),c) == combine(a,combine(b,c))`)
/// is the contract the scans rely on; it is property-tested for every
/// implementation in `elements/`.
pub trait AssocOp<E>: Sync {
    /// The neutral element (used for padding and the down-sweep root).
    fn identity(&self) -> E;
    /// `a ⊗ b` (order matters — the operators here are non-commutative).
    fn combine(&self, a: &E, b: &E) -> E;

    /// Fold `init ⊗ e_0 ⊗ … ⊗ e_{n-1}`. Operators with reusable scratch
    /// (the D×D matrix elements) override this to avoid the per-combine
    /// allocation of the default — the §Perf hot path.
    fn fold(&self, init: E, elems: &[E]) -> E
    where
        E: Clone,
    {
        let mut acc = init;
        for e in elems {
            acc = self.combine(&acc, e);
        }
        acc
    }

    /// One fold step with caller-owned scratch: `acc ← acc ⊗ e`, where
    /// `scratch` is a same-shape element the operator may use as its
    /// output buffer (swap-style). Must be bitwise one step of
    /// [`fold`](Self::fold) — `scan::CheckpointedScan::push` relies on
    /// that to keep steady-state appends allocation-free without
    /// breaking the bit-identity contract. The default allocates via
    /// [`combine`](Self::combine); matrix operators override it.
    fn fold_step(&self, acc: &mut E, e: &E, scratch: &mut E)
    where
        E: Clone,
    {
        let _ = scratch;
        *acc = self.combine(acc, e);
    }

    /// In-place inclusive rescan with an incoming carry:
    /// `elems[i] ← carry ⊗ e_0 ⊗ … ⊗ e_i`. Same override rationale as
    /// [`fold`](Self::fold).
    fn rescan(&self, carry: &E, elems: &mut [E])
    where
        E: Clone,
    {
        let mut acc = carry.clone();
        for e in elems.iter_mut() {
            acc = self.combine(&acc, e);
            *e = acc.clone();
        }
    }

    /// Flipped-orientation fold: `e_{n-1} ⊗ … ⊗ e_0 ⊗ init` — what
    /// [`Flip`] needs so the reversed scans keep the zero-allocation
    /// fast path.
    fn fold_rev(&self, init: E, elems: &[E]) -> E
    where
        E: Clone,
    {
        let mut acc = init;
        for e in elems {
            acc = self.combine(e, &acc);
        }
        acc
    }

    /// Flipped-orientation rescan (see [`fold_rev`](Self::fold_rev)).
    fn rescan_rev(&self, carry: &E, elems: &mut [E])
    where
        E: Clone,
    {
        let mut acc = carry.clone();
        for e in elems.iter_mut() {
            acc = self.combine(e, &acc);
            *e = acc.clone();
        }
    }

    /// Combine every `(j, k)` pair of one up-sweep tree level:
    /// `a[k] ← a[j] ⊗ a[k]`. The pairs of a Blelloch level are pairwise
    /// disjoint, so operators may batch them into one kernel pass — the
    /// D×D matrix operators override this with the SoA batched combine
    /// (`linalg::kernels::batch_matmul_soa`). Overrides must be bitwise
    /// identical to this default loop.
    fn combine_pairs_up(&self, elems: &mut [E], pairs: &[(usize, usize)]) {
        for &(j, k) in pairs {
            elems[k] = self.combine(&elems[j], &elems[k]);
        }
    }

    /// Down-sweep analogue of [`combine_pairs_up`](Self::combine_pairs_up):
    /// per pair, `a[j] ← a[k]` and `a[k] ← a[k]_old ⊗ a[j]_old`. Same
    /// disjointness precondition and bit-identity contract.
    fn combine_pairs_down(&self, elems: &mut [E], pairs: &[(usize, usize)])
    where
        E: Clone,
    {
        for &(j, k) in pairs {
            let t = elems[j].clone();
            elems[j] = elems[k].clone();
            elems[k] = self.combine(&elems[k], &t);
        }
    }
}

/// Elements whose storage can be overwritten in place from a same-shape
/// source — what the buffer-reusing scan paths
/// ([`CheckpointedScan::suffix_into`], the inference workspace copy
/// helpers) need to skip per-call allocation. For heap-backed elements
/// (the D×D matrix families) `overwrite_from` reuses the existing
/// buffers; value-type elements just assign.
pub trait ElementBuf: Clone {
    /// Shape key: two elements with equal keys share buffer layout.
    fn shape_key(&self) -> (usize, usize);
    /// Overwrite `self` from `src` (shapes already verified equal).
    fn overwrite_from(&mut self, src: &Self);
}

/// Flipped operator: `combine(a, b) = inner.combine(b, a)`. Used by the
/// reversed scans (§III-B: "we also need to reverse the operation inside
/// the algorithm").
pub struct Flip<'a, Op>(pub &'a Op);

impl<E, Op: AssocOp<E>> AssocOp<E> for Flip<'_, Op> {
    fn identity(&self) -> E {
        self.0.identity()
    }
    fn combine(&self, a: &E, b: &E) -> E {
        self.0.combine(b, a)
    }
    fn fold(&self, init: E, elems: &[E]) -> E
    where
        E: Clone,
    {
        self.0.fold_rev(init, elems)
    }
    fn rescan(&self, carry: &E, elems: &mut [E])
    where
        E: Clone,
    {
        self.0.rescan_rev(carry, elems)
    }
    fn fold_rev(&self, init: E, elems: &[E]) -> E
    where
        E: Clone,
    {
        self.0.fold(init, elems)
    }
    fn rescan_rev(&self, carry: &E, elems: &mut [E])
    where
        E: Clone,
    {
        self.0.rescan(carry, elems)
    }
}

/// Scan engine selection (see EXPERIMENTS.md §Perf for the comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScanEngine {
    /// Blelloch tree (Algorithm 2): O(log T) span, ~3T combines. The
    /// right schedule when cores ≳ T.
    Blelloch,
    /// Two-level block-wise scan (§V-B): ~2T combines in two
    /// cache-friendly sequential sweeps per block. The right schedule
    /// when cores ≪ T — i.e. on this CPU.
    #[default]
    Chunked,
}

/// In-place sequential inclusive prefix scan:
/// elems[k] ← a_0 ⊗ … ⊗ a_k. Zero allocation beyond the operator's own
/// combines — the form streaming sessions call per append.
pub fn seq_scan_into<E: Clone, Op: AssocOp<E>>(op: &Op, elems: &mut [E]) {
    for k in 1..elems.len() {
        let (prev, cur) = elems.split_at_mut(k);
        let next = op.combine(&prev[k - 1], &cur[0]);
        cur[0] = next;
    }
}

/// In-place sequential inclusive suffix scan:
/// elems[k] ← a_k ⊗ … ⊗ a_{T-1}.
pub fn seq_scan_rev_into<E: Clone, Op: AssocOp<E>>(op: &Op, elems: &mut [E]) {
    for k in (0..elems.len().saturating_sub(1)).rev() {
        let (cur, next) = elems.split_at_mut(k + 1);
        let v = op.combine(&cur[k], &next[0]);
        cur[k] = v;
    }
}

/// Sequential inclusive prefix scan: out[k] = a_0 ⊗ … ⊗ a_k.
/// Thin allocating wrapper over [`seq_scan_into`].
pub fn seq_scan<E: Clone, Op: AssocOp<E>>(op: &Op, elems: &[E]) -> Vec<E> {
    let mut out = elems.to_vec();
    seq_scan_into(op, &mut out);
    out
}

/// Sequential inclusive suffix scan: out[k] = a_k ⊗ … ⊗ a_{T-1}.
/// Thin allocating wrapper over [`seq_scan_rev_into`].
pub fn seq_scan_rev<E: Clone, Op: AssocOp<E>>(op: &Op, elems: &[E]) -> Vec<E> {
    let mut out = elems.to_vec();
    seq_scan_rev_into(op, &mut out);
    out
}

/// Threading configuration for the parallel scans.
#[derive(Debug, Clone, Copy)]
pub struct ScanOptions {
    /// Maximum worker threads per level (1 = single-threaded Blelloch,
    /// still the O(log T)-span *schedule*, executed serially).
    pub threads: usize,
    /// Minimum number of combines per level before threads are used —
    /// below this the spawn overhead dominates (tuned in §Perf).
    pub min_parallel_work: usize,
    /// Which scan schedule `run_scan`/`run_scan_rev` dispatch to.
    pub engine: ScanEngine,
    /// Fixed block length for the chunked engine. `None` (the default)
    /// derives ~4 blocks per thread from the sequence length; a fixed
    /// value makes the block partition length-independent — what
    /// `scan::CheckpointedScan` needs so a streamed scan and a one-shot
    /// scan agree bit-for-bit.
    pub block: Option<usize>,
}

impl Default for ScanOptions {
    fn default() -> Self {
        Self {
            threads: crate::exec::default_parallelism(),
            min_parallel_work: 64,
            engine: ScanEngine::Chunked,
            block: None,
        }
    }
}

impl ScanOptions {
    /// Single-threaded options (parallel dispatch never engages).
    pub fn serial() -> Self {
        Self {
            threads: 1,
            min_parallel_work: usize::MAX,
            engine: ScanEngine::Chunked,
            block: None,
        }
    }

    /// Select the scan schedule (builder-style).
    pub fn with_engine(mut self, engine: ScanEngine) -> Self {
        self.engine = engine;
        self
    }

    /// Pin the chunked engine's block length (see [`ScanOptions::block`]).
    pub fn with_block(mut self, block: usize) -> Self {
        self.block = Some(block.max(1));
        self
    }

    /// Block length for the chunked engine: the pinned [`block`] when
    /// set, otherwise ~4 blocks per thread so the tail imbalance stays
    /// small (tuned in §Perf).
    ///
    /// [`block`]: ScanOptions::block
    pub fn chunk_for(&self, len: usize) -> usize {
        match self.block {
            Some(b) => b.max(1),
            None => len.div_ceil(self.threads.max(1) * 4).max(16),
        }
    }
}

/// Engine-dispatched inclusive prefix scan (used by the inference layer).
pub fn run_scan<E, Op>(op: &Op, elems: &mut [E], opts: ScanOptions)
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    if opts.threads <= 1 && opts.engine == ScanEngine::Chunked {
        // One worker: a single in-place rescan is the work-minimal
        // schedule (T combines; chunked would do 2T).
        let ident = op.identity();
        op.rescan(&ident, elems);
        return;
    }
    match opts.engine {
        ScanEngine::Blelloch => blelloch_scan(op, elems, opts),
        ScanEngine::Chunked => chunked_scan(op, elems, opts.chunk_for(elems.len()), opts),
    }
}

/// Engine-dispatched reversed all-prefix-sums (Definition 2).
pub fn run_scan_rev<E, Op>(op: &Op, elems: &mut [E], opts: ScanOptions)
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    elems.reverse();
    let flipped = Flip(op);
    run_scan(&flipped, elems, opts);
    elems.reverse();
}

/// Blelloch work-efficient inclusive scan (paper Algorithm 2).
///
/// In-place transformation of `elems` into its all-prefix-sums. Arbitrary
/// T is handled by operating on the implicit next-power-of-two tree and
/// skipping out-of-range nodes (identity padding never materializes).
///
/// Span O(log T) with ≥ T/2 processors; work O(T).
pub fn blelloch_scan<E, Op>(op: &Op, elems: &mut [E], opts: ScanOptions)
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    let t = elems.len();
    if t <= 1 {
        return;
    }

    let root = largest_pow2_leq(t);
    if root != t {
        // Arbitrary T (Algorithm 2 note): scan the power-of-two head and
        // the remainder tail independently (concurrently — this adds one
        // level to the span), then push the head's total into the tail.
        let (head, tail) = elems.split_at_mut(root);
        if opts.threads > 1 && t >= opts.min_parallel_work {
            crate::exec::scope_join(
                || blelloch_scan(op, head, opts),
                || blelloch_scan(op, tail, opts),
            );
        } else {
            blelloch_scan(op, head, opts);
            blelloch_scan(op, tail, opts);
        }
        let acc = head[root - 1].clone();
        for e in tail.iter_mut() {
            *e = op.combine(&acc, e);
        }
        return;
    }

    // Power-of-two in-place Blelloch.
    // Save inputs for the final inclusive pass (Algorithm 2 lines 1-4).
    let saved: Vec<E> = elems.to_vec();
    let levels = t.trailing_zeros(); // log2 t exactly

    // Up-sweep (lines 5-12): a[k] ← a[j] ⊗ a[k] over a balanced tree.
    for d in 0..levels {
        let stride = 1usize << (d + 1);
        let half = 1usize << d;
        let starts: Vec<usize> = (0..t).step_by(stride).collect();
        run_level(op, elems, &starts, half, stride, opts, false);
    }

    // Root ← identity (line 13), then down-sweep (lines 14-23) computes
    // the exclusive scan.
    elems[t - 1] = op.identity();
    for d in (0..levels).rev() {
        let stride = 1usize << (d + 1);
        let half = 1usize << d;
        let starts: Vec<usize> = (0..t).step_by(stride).collect();
        run_level(op, elems, &starts, half, stride, opts, true);
    }

    // Final inclusive pass (lines 24-27): a[i] ← a[i] ⊗ b[i].
    finalize_inclusive(op, elems, &saved, opts);
}

/// Reversed all-prefix-sums (Definition 2): out[k] = a_k ⊗ … ⊗ a_{T-1},
/// computed per §III-B by reversing inputs, flipping the operator,
/// scanning, and reversing outputs.
pub fn scan_rev<E, Op>(op: &Op, elems: &mut [E], opts: ScanOptions)
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    elems.reverse();
    let flipped = Flip(op);
    blelloch_scan(&flipped, elems, opts);
    elems.reverse();
}

/// Two-level block-wise scan (paper §V-B): fold `block`-sized chunks
/// sequentially (one "computational element" per chunk), scan the chunk
/// summaries, then finalize each chunk with its incoming prefix.
/// This is the CPU-friendly schedule when cores ≪ T and exactly the
/// protocol the coordinator's temporal sharder runs over PJRT workers.
pub fn chunked_scan<E, Op>(op: &Op, elems: &mut [E], block: usize, opts: ScanOptions)
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    let t = elems.len();
    if t == 0 {
        return;
    }
    let block = block.max(1);
    let nblocks = t.div_ceil(block);
    if nblocks == 1 {
        seq_scan_into(op, elems);
        return;
    }

    // Phase 1 (parallel over blocks): fold each block to its summary.
    let mut summaries: Vec<E> = vec![op.identity(); nblocks];
    {
        let out = crate::exec::SharedSliceMut::new(&mut summaries);
        let elems_ref: &[E] = elems;
        parallel_for_chunks(nblocks, opts.threads, |_, lo, hi| {
            for b in lo..hi {
                let start = b * block;
                let end = (start + block).min(t);
                let acc = op.fold(elems_ref[start].clone(), &elems_ref[start + 1..end]);
                // SAFETY: each summary slot b is written by exactly one
                // chunk (chunks partition 0..nblocks).
                unsafe { out.write(b, acc) };
            }
        });
    }

    // Phase 2: exclusive scan of summaries (small — sequential).
    let mut carry = op.identity();
    let mut incoming: Vec<E> = Vec::with_capacity(nblocks);
    for s in &summaries {
        incoming.push(carry.clone());
        carry = op.combine(&carry, s);
    }

    // Phase 3 (parallel over blocks): rescan each block with its carry.
    {
        let base = crate::exec::SharedSliceMut::new(elems);
        let incoming_ref = &incoming;
        parallel_for_chunks(nblocks, opts.threads, |_, lo, hi| {
            for b in lo..hi {
                let start = b * block;
                let end = (start + block).min(base.len());
                // SAFETY: blocks are disjoint ranges of the slice.
                let slice = unsafe { base.range_mut(start, end) };
                op.rescan(&incoming_ref[b], slice);
            }
        });
    }
}

// ---------------------------------------------------------------------------
// internals
// ---------------------------------------------------------------------------

/// One Blelloch tree level: gather the in-range `(j, k)` node pairs and
/// hand them to the operator's pair hooks ([`AssocOp::combine_pairs_up`]
/// / [`AssocOp::combine_pairs_down`]) — serially, or chunked across
/// threads. Routing whole levels through the pair hooks is what lets
/// the matrix operators combine an entire level in one batched SoA
/// kernel pass instead of one matmul per node.
fn run_level<E, Op>(
    op: &Op,
    elems: &mut [E],
    starts: &[usize],
    half: usize,
    stride: usize,
    opts: ScanOptions,
    down: bool,
) where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    let t = elems.len();
    let pairs: Vec<(usize, usize)> = starts
        .iter()
        .filter_map(|&i| {
            let j = i + half - 1;
            let k = i + stride - 1;
            (j < t && k < t).then_some((j, k))
        })
        .collect();
    if pairs.is_empty() {
        return;
    }
    let apply = |a: &mut [E], ps: &[(usize, usize)]| {
        if down {
            op.combine_pairs_down(a, ps);
        } else {
            op.combine_pairs_up(a, ps);
        }
    };
    if pairs.len() < opts.min_parallel_work || opts.threads <= 1 {
        apply(elems, &pairs);
    } else {
        // Disjoint (j, k) pairs per level: chunk the pairs across
        // threads; each pair touches only its own two indices.
        let base = crate::exec::SharedSliceMut::new(elems);
        parallel_for_chunks(pairs.len(), opts.threads, |_, lo, hi| {
            // SAFETY: every pair's (j, k) indices are unique to that
            // pair at a given level, so chunks never alias.
            let a = unsafe { base.full_mut() };
            apply(a, &pairs[lo..hi]);
        });
    }
}

fn finalize_inclusive<E, Op>(op: &Op, elems: &mut [E], saved: &[E], opts: ScanOptions)
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    if elems.len() < opts.min_parallel_work || opts.threads <= 1 {
        for (e, b) in elems.iter_mut().zip(saved) {
            *e = op.combine(e, b);
        }
    } else {
        let base = crate::exec::SharedSliceMut::new(elems);
        parallel_for_chunks(base.len(), opts.threads, |_, lo, hi| {
            // SAFETY: lo..hi ranges partition the slice across chunks.
            let a = unsafe { base.range_mut(lo, hi) };
            for (e, s) in a.iter_mut().zip(&saved[lo..hi]) {
                *e = op.combine(e, s);
            }
        });
    }
}

fn largest_pow2_leq(n: usize) -> usize {
    1usize << (usize::BITS - 1 - n.leading_zeros())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;

    /// Non-commutative test operator: 2×2 integer-ish matrix product.
    struct MatOp;
    type M2 = [f64; 4];

    impl AssocOp<M2> for MatOp {
        fn identity(&self) -> M2 {
            [1.0, 0.0, 0.0, 1.0]
        }
        fn combine(&self, a: &M2, b: &M2) -> M2 {
            [
                a[0] * b[0] + a[1] * b[2],
                a[0] * b[1] + a[1] * b[3],
                a[2] * b[0] + a[3] * b[2],
                a[2] * b[1] + a[3] * b[3],
            ]
        }
    }

    /// String concatenation — the canonical non-commutative monoid; makes
    /// ordering bugs (the reverse-scan flip) immediately visible.
    struct ConcatOp;
    impl AssocOp<String> for ConcatOp {
        fn identity(&self) -> String {
            String::new()
        }
        fn combine(&self, a: &String, b: &String) -> String {
            format!("{a}{b}")
        }
    }

    fn rand_m2(r: &mut crate::rng::Xoshiro256StarStar) -> M2 {
        // near-stochastic to keep products bounded
        let a = r.uniform(0.1, 1.0);
        let b = r.uniform(0.1, 1.0);
        [a, 1.0 - a, b, 1.0 - b]
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn blelloch_matches_seq_scan_all_lengths() {
        let op = MatOp;
        let mut runner = Runner::new("scan-blelloch");
        for t in [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 33, 64, 100, 257] {
            runner.run(3, |r| {
                let elems: Vec<M2> = (0..t).map(|_| rand_m2(r)).collect();
                let want = seq_scan(&op, &elems);
                let mut got = elems.clone();
                blelloch_scan(&op, &mut got, ScanOptions::serial());
                for (w, g) in want.iter().zip(&got) {
                    assert!(w.iter().zip(g).all(|(&x, &y)| close(x, y)), "t={t}");
                }
                // threaded variant
                let mut got2 = elems;
                blelloch_scan(
                    &op,
                    &mut got2,
                    ScanOptions { threads: 4, min_parallel_work: 2, ..ScanOptions::default() },
                );
                for (w, g) in want.iter().zip(&got2) {
                    assert!(w.iter().zip(g).all(|(&x, &y)| close(x, y)), "t={t} mt");
                }
            });
        }
    }

    #[test]
    fn blelloch_ordering_noncommutative() {
        let op = ConcatOp;
        for t in [1usize, 2, 3, 6, 8, 13, 16, 31] {
            let elems: Vec<String> = (0..t).map(|i| format!("{i},")).collect();
            let mut got = elems.clone();
            blelloch_scan(&op, &mut got, ScanOptions::serial());
            let want = seq_scan(&op, &elems);
            assert_eq!(got, want, "t={t}");
        }
    }

    #[test]
    fn scan_rev_matches_seq_rev() {
        let op = ConcatOp;
        for t in [1usize, 2, 5, 8, 12, 16, 27] {
            let elems: Vec<String> = (0..t).map(|i| format!("{i},")).collect();
            let want = seq_scan_rev(&op, &elems);
            let mut got = elems.clone();
            scan_rev(&op, &mut got, ScanOptions::serial());
            assert_eq!(got, want, "t={t}");
            let mut got2 = elems;
            scan_rev(
                &op,
                &mut got2,
                ScanOptions { threads: 3, min_parallel_work: 2, ..ScanOptions::default() },
            );
            assert_eq!(got2, want, "t={t} mt");
        }
    }

    #[test]
    fn chunked_scan_matches_seq() {
        let op = ConcatOp;
        let mut runner = Runner::new("scan-chunked");
        runner.run(10, |r| {
            let t = 1 + r.below(200) as usize;
            let block = 1 + r.below(40) as usize;
            let elems: Vec<String> = (0..t).map(|i| format!("{i},")).collect();
            let want = seq_scan(&op, &elems);
            let mut got = elems;
            chunked_scan(
                &op,
                &mut got,
                block,
                ScanOptions { threads: 4, min_parallel_work: 1, ..ScanOptions::default() },
            );
            assert_eq!(got, want, "t={t} block={block}");
        });
    }

    #[test]
    fn chunked_scan_t_one_and_non_divisible_blocks() {
        let op = ConcatOp;
        // T = 1 collapses to the single-block sequential path.
        let mut one = vec!["a".to_string()];
        chunked_scan(&op, &mut one, 8, ScanOptions::default());
        assert_eq!(one, vec!["a".to_string()]);
        // T not divisible by the block size: the tail block is short and
        // must still receive the correct incoming carry.
        for (t, block) in
            [(103usize, 16usize), (17, 4), (5, 2), (9, 8), (16, 16), (31, 16)]
        {
            let elems: Vec<String> = (0..t).map(|i| format!("{i},")).collect();
            let want = seq_scan(&op, &elems);
            let mut got = elems.clone();
            chunked_scan(
                &op,
                &mut got,
                block,
                ScanOptions { threads: 3, min_parallel_work: 1, ..ScanOptions::default() },
            );
            assert_eq!(got, want, "t={t} block={block} (threaded)");
            let mut got = elems;
            chunked_scan(&op, &mut got, block, ScanOptions::serial());
            assert_eq!(got, want, "t={t} block={block} (serial)");
        }
    }

    #[test]
    fn seq_scan_into_matches_wrappers() {
        let op = ConcatOp;
        for t in [0usize, 1, 2, 3, 7, 16, 33] {
            let elems: Vec<String> = (0..t).map(|i| format!("{i},")).collect();
            let mut fwd = elems.clone();
            seq_scan_into(&op, &mut fwd);
            assert_eq!(fwd, seq_scan(&op, &elems), "fwd t={t}");
            let mut bwd = elems.clone();
            seq_scan_rev_into(&op, &mut bwd);
            assert_eq!(bwd, seq_scan_rev(&op, &elems), "bwd t={t}");
        }
    }

    #[test]
    fn fixed_block_pins_the_chunk_partition() {
        let opts = ScanOptions::default().with_block(32);
        assert_eq!(opts.chunk_for(10), 32);
        assert_eq!(opts.chunk_for(100_000), 32);
        let auto = ScanOptions { threads: 4, ..ScanOptions::default() };
        assert_eq!(auto.chunk_for(16_000), 1000);
        // run_scan under a pinned block matches the sequential oracle.
        let op = ConcatOp;
        for t in [1usize, 31, 32, 33, 200] {
            let elems: Vec<String> = (0..t).map(|i| format!("{i},")).collect();
            let want = seq_scan(&op, &elems);
            let mut got = elems;
            run_scan(
                &op,
                &mut got,
                ScanOptions {
                    threads: 3,
                    min_parallel_work: 1,
                    ..ScanOptions::default().with_block(32)
                },
            );
            assert_eq!(got, want, "t={t}");
        }
    }

    #[test]
    fn empty_and_singleton() {
        let op = ConcatOp;
        let mut empty: Vec<String> = vec![];
        blelloch_scan(&op, &mut empty, ScanOptions::default());
        scan_rev(&op, &mut empty, ScanOptions::default());
        chunked_scan(&op, &mut empty, 8, ScanOptions::default());
        assert!(empty.is_empty());

        let mut one = vec!["x".to_string()];
        blelloch_scan(&op, &mut one, ScanOptions::default());
        assert_eq!(one, vec!["x".to_string()]);
    }

    #[test]
    fn flip_flips() {
        let op = ConcatOp;
        let f = Flip(&op);
        assert_eq!(
            f.combine(&"a".to_string(), &"b".to_string()),
            "ba".to_string()
        );
    }

    #[test]
    fn blelloch_kernels_on_vs_off_bitwise_matrix_elements() {
        // The Blelloch sweeps route whole levels through the batched
        // pair hooks when kernels are on; with kernels off every pair
        // takes the per-pair generic path. Same schedule, so the
        // results must agree bit for bit — across non-power-of-two
        // lengths (short tail levels, pairs.len() == 1) and both
        // serial and threaded execution.
        use crate::elements::{MpElement, MpOp, SpElement, SpOp};
        use crate::linalg::kernels::{set_kernels_enabled, toggle_guard};
        use crate::linalg::Mat;
        use crate::proptestx::assert_bits_eq;
        let _guard = toggle_guard();
        let mut runner = Runner::new("scan-kernels-on-off");
        for t in [3usize, 5, 6, 7, 9, 12, 17, 33, 100, 257] {
            runner.run(2, |r| {
                for d in [2usize, 3, 4] {
                    let sp_op = SpOp { d };
                    let elems: Vec<SpElement> = (0..t)
                        .map(|_| {
                            let m = Mat::from_vec(
                                d,
                                d,
                                (0..d * d).map(|_| r.uniform(0.01, 1.0)).collect(),
                            );
                            SpElement::from_mat(m)
                        })
                        .collect();
                    let mp_op = MpOp { d };
                    let melems: Vec<MpElement> = (0..t)
                        .map(|_| MpElement {
                            mat: Mat::from_vec(
                                d,
                                d,
                                (0..d * d).map(|_| r.uniform(-8.0, 0.0)).collect(),
                            ),
                        })
                        .collect();
                    for opts in [
                        ScanOptions::serial(),
                        ScanOptions {
                            threads: 4,
                            min_parallel_work: 2,
                            ..ScanOptions::default()
                        },
                    ] {
                        set_kernels_enabled(true);
                        let mut on = elems.clone();
                        blelloch_scan(&sp_op, &mut on, opts);
                        let mut mon = melems.clone();
                        blelloch_scan(&mp_op, &mut mon, opts);
                        set_kernels_enabled(false);
                        let mut off = elems.clone();
                        blelloch_scan(&sp_op, &mut off, opts);
                        let mut moff = melems.clone();
                        blelloch_scan(&mp_op, &mut moff, opts);
                        for (g, w) in on.iter().zip(&off) {
                            assert_bits_eq("sp scan", g.mat.data(), w.mat.data());
                            assert_eq!(g.log_scale.to_bits(), w.log_scale.to_bits());
                        }
                        for (g, w) in mon.iter().zip(&moff) {
                            assert_bits_eq("mp scan", g.mat.data(), w.mat.data());
                        }
                    }
                }
            });
        }
        set_kernels_enabled(true);
    }

    #[test]
    fn large_scan_stress() {
        let op = MatOp;
        let mut runner = Runner::new("scan-stress");
        runner.run(2, |r| {
            let t = 5000 + r.below(3000) as usize;
            let elems: Vec<M2> = (0..t).map(|_| rand_m2(r)).collect();
            let want = seq_scan(&op, &elems);
            let mut got = elems;
            blelloch_scan(&op, &mut got, ScanOptions::default());
            let last_w = want.last().unwrap();
            let last_g = got.last().unwrap();
            assert!(last_w.iter().zip(last_g).all(|(&x, &y)| close(x, y)));
        });
    }
}

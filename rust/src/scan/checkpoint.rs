//! Resumable prefix scans — the streaming substrate of `engine::Session`.
//!
//! [`CheckpointedScan`] holds the element chain of a growing sequence
//! together with the *per-block summaries* the two-level [`chunked_scan`]
//! (paper §V-B) computes in its phase 1, and the exclusive block carries
//! of its phase 2. Appending k elements costs k summary-fold steps; the
//! current filtering prefix is one combine away; and materializing the
//! full all-prefix-sums needs only phase 3 (one rescan per block — half
//! the combines of a from-scratch chunked scan).
//!
//! **Bit-identity contract.** Every floating-point operation performed
//! here is the same operation, in the same order, that `run_scan` with a
//! pinned block length ([`ScanOptions::block`]) would perform on the
//! full chain:
//!
//! * incremental summary folds replay `AssocOp::fold`'s per-element step
//!   (phase 1),
//! * carries are the same left-fold of summaries (phase 2),
//! * [`materialize_into`](CheckpointedScan::materialize_into) replays
//!   `run_scan`'s dispatch (single-rescan shortcut, single-block
//!   sequential path, or per-block phase-3 rescans).
//!
//! So `Session::finish()` is bit-identical to the one-shot
//! `Engine::run(Algorithm::SpPar, ..)` under the same scan options —
//! property-tested over random push splits in `engine::tests`.

use crate::error::{Error, Result};
use crate::exec::{parallel_for_chunks, SharedSliceMut};

use super::{seq_scan_into, AssocOp, ElementBuf, ScanEngine, ScanOptions};

/// A resumable inclusive prefix scan over a growing element chain.
///
/// State (for chain length T, block length B):
///
/// ```text
/// elems:     [ e_0 … e_{B-1} | e_B … e_{2B-1} | … | tail (< B elems) ]
/// summaries: [   s_0 = ⊗blk0 |   s_1 = ⊗blk1 | … ]          (⌊T/B⌋)
/// carries:   [ id | id⊗s_0 | id⊗s_0⊗s_1 | … ]               (⌊T/B⌋+1)
/// tail_acc:  fold of the current partial block (None when T % B = 0)
/// ```
pub struct CheckpointedScan<E, Op> {
    op: Op,
    block: usize,
    elems: Vec<E>,
    summaries: Vec<E>,
    carries: Vec<E>,
    tail_acc: Option<E>,
    /// Operator scratch for the per-push fold step (same shape as the
    /// elements), so steady-state appends perform zero transient
    /// allocations — asserted by `push_steady_state_is_allocation_free`.
    scratch: E,
}

impl<E, Op> CheckpointedScan<E, Op>
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    /// Empty scan with block length `block` (clamped to ≥ 1).
    pub fn new(op: Op, block: usize) -> Self {
        let carries = vec![op.identity()];
        let scratch = op.identity();
        Self {
            op,
            block: block.max(1),
            elems: Vec::new(),
            summaries: Vec::new(),
            carries,
            tail_acc: None,
            scratch,
        }
    }

    /// Rebuild a scan from exported state (session resume after
    /// eviction): the raw chain plus the serialized block summaries and
    /// tail accumulator. Carries are re-derived — ⌊T/B⌋ combines instead
    /// of the O(T) refold the summaries replace.
    pub fn from_parts(
        op: Op,
        block: usize,
        elems: Vec<E>,
        summaries: Vec<E>,
        tail_acc: Option<E>,
    ) -> Result<Self> {
        let block = block.max(1);
        if summaries.len() != elems.len() / block {
            return Err(Error::invalid_request(format!(
                "checkpoint restore: {} summaries for {} elements at block {}",
                summaries.len(),
                elems.len(),
                block
            )));
        }
        if tail_acc.is_some() != (elems.len() % block != 0) {
            return Err(Error::invalid_request(
                "checkpoint restore: tail accumulator presence mismatch",
            ));
        }
        let mut carries = Vec::with_capacity(summaries.len() + 1);
        carries.push(op.identity());
        for s in &summaries {
            let c = op.combine(carries.last().expect("seeded"), s);
            carries.push(c);
        }
        let scratch = op.identity();
        Ok(Self { op, block, elems, summaries, carries, tail_acc, scratch })
    }

    /// Number of elements appended so far.
    pub fn len(&self) -> usize {
        self.elems.len()
    }

    /// Whether nothing has been appended yet.
    pub fn is_empty(&self) -> bool {
        self.elems.is_empty()
    }

    /// The pinned block length B.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Number of completed-block checkpoints (⌊T/B⌋).
    pub fn num_checkpoints(&self) -> usize {
        self.summaries.len()
    }

    /// The raw element chain (e_0 … e_{T-1}).
    pub fn elems(&self) -> &[E] {
        &self.elems
    }

    /// Completed-block summaries (the exported eviction state).
    pub fn summaries(&self) -> &[E] {
        &self.summaries
    }

    /// Fold of the current partial block, if any.
    pub fn tail_acc(&self) -> Option<&E> {
        self.tail_acc.as_ref()
    }

    /// Append one element: O(1) combines (one summary-fold step, plus
    /// one carry combine when a block completes). The fold step runs
    /// through the op-owned scratch ([`AssocOp::fold_step`]), so
    /// interior-of-block appends allocate nothing beyond the retained
    /// element itself.
    pub fn push(&mut self, e: E) {
        self.elems.push(e);
        let e_ref = self.elems.last().expect("just pushed");
        // Phase-1 replay: fold's init is the block's first element; each
        // later element advances the accumulator by one fold step.
        let acc = match self.tail_acc.take() {
            None => e_ref.clone(),
            Some(mut prev) => {
                self.op.fold_step(&mut prev, e_ref, &mut self.scratch);
                prev
            }
        };
        if self.elems.len() % self.block == 0 {
            // Phase-2 replay: carry ← carry ⊗ summary.
            let carry = self.op.combine(self.carries.last().expect("seeded"), &acc);
            self.summaries.push(acc);
            self.carries.push(carry);
        } else {
            self.tail_acc = Some(acc);
        }
    }

    /// Append a batch of elements.
    pub fn extend(&mut self, elems: impl IntoIterator<Item = E>) {
        for e in elems {
            self.push(e);
        }
    }

    /// Pre-grow the element chain (and its checkpoint stores) for
    /// `additional` more pushes, so a burst of appends of known size
    /// performs no vector reallocation mid-stream.
    pub fn reserve(&mut self, additional: usize) {
        self.elems.reserve(additional);
        let blocks = additional / self.block + 1;
        self.summaries.reserve(blocks);
        self.carries.reserve(blocks);
    }

    /// The inclusive total a_0 ⊗ … ⊗ a_{T-1} — the *filtering* prefix.
    /// One combine (carry ⊗ tail fold); identity when empty.
    pub fn prefix(&self) -> E {
        let carry = self.carries.last().expect("seeded");
        match &self.tail_acc {
            Some(tail) => self.op.combine(carry, tail),
            None => carry.clone(),
        }
    }

    /// Inclusive prefix values for the suffix window covering absolute
    /// indices `start..len`: rescans raw elements from the checkpoint at
    /// or before `start`, one block at a time with the stored carries.
    /// Returns the absolute index of `out[0]` (≤ `start`, within one
    /// block of it), so the rescan width is at most `len - start + B`.
    ///
    /// On complete blocks the values are bitwise those of
    /// [`materialize_into`](Self::materialize_into)'s chunked path; the
    /// cost is O(len − start + B) combines instead of O(len). `out`'s
    /// existing same-shape elements are overwritten in place
    /// ([`ElementBuf`]) — the steady-state fixed-lag query allocates
    /// only when the window outgrows the previous one.
    pub fn suffix_into(&self, start: usize, out: &mut Vec<E>) -> usize
    where
        E: ElementBuf,
    {
        let start = start.min(self.elems.len());
        let b0 = start / self.block;
        let from = b0 * self.block;
        let src = &self.elems[from..];
        let same_shape = match (out.first(), src.first()) {
            (Some(d), Some(s)) => d.shape_key() == s.shape_key(),
            _ => false,
        };
        if same_shape {
            out.truncate(src.len());
            let k = out.len();
            for (d, s) in out.iter_mut().zip(&src[..k]) {
                d.overwrite_from(s);
            }
            out.extend(src[k..].iter().cloned());
        } else {
            out.clear();
            out.extend(src.iter().cloned());
        }
        let mut b = b0;
        let mut off = 0;
        while off < out.len() {
            let end = (off + self.block).min(out.len());
            self.op.rescan(&self.carries[b], &mut out[off..end]);
            b += 1;
            off = end;
        }
        from
    }

    /// Materialize the full all-prefix-sums into `out`, bit-identical to
    /// `run_scan(&op, full_chain, opts)` for options that pin this
    /// scan's block length — but skipping the chunked engine's phases
    /// 1–2 (already checkpointed), so only one rescan per block runs.
    pub fn materialize_into(&self, out: &mut Vec<E>, opts: ScanOptions) {
        out.clear();
        out.extend(self.elems.iter().cloned());
        let t = out.len();
        if t == 0 {
            return;
        }
        debug_assert_eq!(
            opts.chunk_for(t),
            self.block,
            "scan options must pin the checkpoint block length"
        );
        // run_scan's one-worker shortcut: a single in-place rescan.
        if opts.threads <= 1 && opts.engine == ScanEngine::Chunked {
            let ident = self.op.identity();
            self.op.rescan(&ident, out);
            return;
        }
        if opts.engine == ScanEngine::Blelloch {
            // No checkpoint reuse for the tree schedule — correctness
            // fallback only; sessions pin the chunked engine.
            super::blelloch_scan(&self.op, out, opts);
            return;
        }
        let nblocks = t.div_ceil(self.block);
        if nblocks == 1 {
            seq_scan_into(&self.op, out);
            return;
        }
        // chunked_scan phase 3: rescan each block with its stored carry.
        let block = self.block;
        let op = &self.op;
        let carries = &self.carries;
        let base = SharedSliceMut::new(out.as_mut_slice());
        parallel_for_chunks(nblocks, opts.threads, |_, lo, hi| {
            for b in lo..hi {
                let start = b * block;
                let end = (start + block).min(base.len());
                // SAFETY: blocks are disjoint ranges of the slice.
                let slice = unsafe { base.range_mut(start, end) };
                op.rescan(&carries[b], slice);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;
    use crate::scan::{chunked_scan, run_scan};

    /// Non-commutative 2×2 matrix product — makes both ordering and
    /// floating-point association bugs visible via exact equality.
    struct MatOp;
    type M2 = [f64; 4];

    impl AssocOp<M2> for MatOp {
        fn identity(&self) -> M2 {
            [1.0, 0.0, 0.0, 1.0]
        }
        fn combine(&self, a: &M2, b: &M2) -> M2 {
            [
                a[0] * b[0] + a[1] * b[2],
                a[0] * b[1] + a[1] * b[3],
                a[2] * b[0] + a[3] * b[2],
                a[2] * b[1] + a[3] * b[3],
            ]
        }
    }

    struct ConcatOp;
    impl AssocOp<String> for ConcatOp {
        fn identity(&self) -> String {
            String::new()
        }
        fn combine(&self, a: &String, b: &String) -> String {
            format!("{a}{b}")
        }
    }

    // Test-only ElementBuf impls so `suffix_into` works with the toy
    // element types (no meaningful shape — assignment semantics).
    impl ElementBuf for M2 {
        fn shape_key(&self) -> (usize, usize) {
            (2, 2)
        }
        fn overwrite_from(&mut self, src: &Self) {
            *self = *src;
        }
    }

    impl ElementBuf for String {
        fn shape_key(&self) -> (usize, usize) {
            (0, 0)
        }
        fn overwrite_from(&mut self, src: &Self) {
            self.clone_from(src);
        }
    }

    fn rand_m2(r: &mut crate::rng::Xoshiro256StarStar) -> M2 {
        let a = r.uniform(0.1, 1.0);
        let b = r.uniform(0.1, 1.0);
        [a, 1.0 - a, b, 1.0 - b]
    }

    fn mt_opts(block: usize) -> ScanOptions {
        ScanOptions {
            threads: 3,
            min_parallel_work: 1,
            ..ScanOptions::default().with_block(block)
        }
    }

    #[test]
    fn incremental_summaries_match_chunked_phases_bitwise() {
        let mut runner = Runner::new("ckpt-phases");
        runner.run(8, |r| {
            let t = 1 + r.below(200) as usize;
            let block = 1 + r.below(24) as usize;
            let elems: Vec<M2> = (0..t).map(|_| rand_m2(r)).collect();

            let mut ck = CheckpointedScan::new(MatOp, block);
            ck.extend(elems.iter().copied());

            // Phase-1 oracle: fold each complete block from scratch.
            for (b, s) in ck.summaries().iter().enumerate() {
                let start = b * block;
                let want =
                    MatOp.fold(elems[start], &elems[start + 1..start + block]);
                assert_eq!(*s, want, "summary {b} (t={t} B={block})");
            }

            // Materialized scan ≡ chunked_scan with the same block —
            // bitwise.
            let opts = mt_opts(block);
            let mut want = elems.clone();
            chunked_scan(&MatOp, &mut want, block, opts);
            let mut got = Vec::new();
            ck.materialize_into(&mut got, opts);
            assert_eq!(got, want, "t={t} B={block}");
        });
    }

    #[test]
    fn materialize_matches_run_scan_all_dispatch_paths() {
        let op = ConcatOp;
        for (t, block) in [(1usize, 8usize), (5, 8), (8, 8), (9, 8), (40, 7)] {
            let elems: Vec<String> = (0..t).map(|i| format!("{i},")).collect();
            let mut ck = CheckpointedScan::new(ConcatOp, block);
            ck.extend(elems.iter().cloned());
            // threaded chunked, serial shortcut — both must agree with
            // run_scan under the same options.
            for opts in [
                mt_opts(block),
                ScanOptions {
                    threads: 1,
                    min_parallel_work: usize::MAX,
                    ..ScanOptions::default().with_block(block)
                },
            ] {
                let mut want = elems.clone();
                run_scan(&op, &mut want, opts);
                let mut got = Vec::new();
                ck.materialize_into(&mut got, opts);
                assert_eq!(got, want, "t={t} B={block} threads={}", opts.threads);
            }
        }
    }

    #[test]
    fn prefix_tracks_the_running_total() {
        let op = ConcatOp;
        let mut ck = CheckpointedScan::new(ConcatOp, 4);
        assert_eq!(ck.prefix(), op.identity());
        let mut want = String::new();
        for i in 0..23 {
            let e = format!("{i},");
            want.push_str(&e);
            ck.push(e);
            assert_eq!(ck.prefix(), want, "after {} pushes", i + 1);
        }
        assert_eq!(ck.num_checkpoints(), 5);
    }

    #[test]
    fn suffix_window_matches_materialized_values() {
        let mut runner = Runner::new("ckpt-suffix");
        runner.run(8, |r| {
            let t = 2 + r.below(150) as usize;
            let block = 2 + r.below(16) as usize;
            let elems: Vec<M2> = (0..t).map(|_| rand_m2(r)).collect();
            let mut ck = CheckpointedScan::new(MatOp, block);
            ck.extend(elems.iter().copied());
            let opts = mt_opts(block);
            let mut full = Vec::new();
            ck.materialize_into(&mut full, opts);

            let start = r.below(t as u64) as usize;
            let mut win = Vec::new();
            let from = ck.suffix_into(start, &mut win);
            assert!(from <= start && start - from < block, "offset");
            assert_eq!(from % block, 0);
            assert_eq!(win.len(), t - from);
            if t > block {
                // multi-block: phase-3 replay is bitwise.
                for (i, w) in win.iter().enumerate() {
                    assert_eq!(*w, full[from + i], "k={}", from + i);
                }
            }
        });
    }

    #[test]
    fn from_parts_round_trips() {
        let elems: Vec<String> = (0..29).map(|i| format!("{i},")).collect();
        let mut ck = CheckpointedScan::new(ConcatOp, 8);
        ck.extend(elems.iter().cloned());
        let restored = CheckpointedScan::from_parts(
            ConcatOp,
            8,
            ck.elems().to_vec(),
            ck.summaries().to_vec(),
            ck.tail_acc().cloned(),
        )
        .unwrap();
        let opts = mt_opts(8);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        ck.materialize_into(&mut a, opts);
        restored.materialize_into(&mut b, opts);
        assert_eq!(a, b);
        assert_eq!(restored.prefix(), ck.prefix());

        // Restored scans keep streaming correctly.
        let mut restored = restored;
        let mut ck = ck;
        for i in 29..40 {
            let e = format!("{i},");
            ck.push(e.clone());
            restored.push(e);
        }
        assert_eq!(restored.prefix(), ck.prefix());

        // Inconsistent parts are rejected.
        assert!(CheckpointedScan::from_parts(
            ConcatOp,
            8,
            vec!["a".to_string(); 10],
            vec![],
            Some("x".to_string()),
        )
        .is_err());
        assert!(CheckpointedScan::from_parts(
            ConcatOp,
            8,
            vec!["a".to_string(); 16],
            vec!["s".to_string(); 2],
            Some("x".to_string()),
        )
        .is_err());
    }

    #[test]
    fn push_steady_state_is_allocation_free() {
        use crate::elements::{SpElement, SpOp};
        use crate::linalg::kernels::{set_kernels_enabled, toggle_guard};
        use crate::linalg::Mat;
        use crate::proptestx::alloc_count;

        // Pin the kernel tier on (a pure atomic store) before the
        // measured window: the first `kernels_enabled()` call would
        // otherwise read the environment inside it, which allocates.
        // The guard keeps other tests from flipping the process-wide
        // toggle mid-measurement — D=4 pushes must stay allocation-free
        // *through the specialized dispatch path*.
        let _guard = toggle_guard();
        set_kernels_enabled(true);

        let d = 4usize;
        let block = 8usize;
        let proto = SpElement::from_mat(Mat::from_vec(
            d,
            d,
            (0..d * d).map(|i| 0.1 + (i as f64) * 0.03).collect(),
        ));
        let mut ck = CheckpointedScan::new(SpOp { d }, block);
        // Warm past the first blocks and seed the tail accumulator, then
        // reserve so the chain vector cannot grow mid-measurement.
        for _ in 0..(2 * block + 1) {
            ck.push(proto.clone());
        }
        ck.reserve(block);
        // Interior-of-block pushes: the retained elements are cloned
        // outside the measured window, so the fold steps themselves must
        // perform zero allocations (the op-owned scratch).
        let pending: Vec<SpElement> =
            (0..block - 2).map(|_| proto.clone()).collect();
        let n = pending.len();
        let before = alloc_count::current();
        for e in pending {
            ck.push(e);
        }
        let delta = alloc_count::current() - before;
        assert_eq!(delta, 0, "steady-state push allocated ({delta} allocs / {n} pushes)");
        // Sanity: the scratch-carrying fold steps are bitwise the
        // allocating `fold` — replay phases 1–2 with `fold`/`combine`
        // and compare the running prefix exactly.
        let op = SpOp { d };
        let t = 2 * block + 1 + n;
        let elems = vec![proto; t];
        let mut carry = op.identity();
        for b in 0..t / block {
            let s = op.fold(
                elems[b * block].clone(),
                &elems[b * block + 1..(b + 1) * block],
            );
            carry = op.combine(&carry, &s);
        }
        let blocks = t / block;
        let tail = op.fold(
            elems[blocks * block].clone(),
            &elems[blocks * block + 1..t],
        );
        let want = op.combine(&carry, &tail);
        assert_eq!(ck.prefix(), want);
    }

    #[test]
    fn empty_scan_edge_cases() {
        let ck: CheckpointedScan<String, ConcatOp> =
            CheckpointedScan::new(ConcatOp, 4);
        assert!(ck.is_empty());
        assert_eq!(ck.prefix(), String::new());
        let mut out = vec!["junk".to_string()];
        ck.materialize_into(&mut out, mt_opts(4));
        assert!(out.is_empty());
        let from = ck.suffix_into(0, &mut out);
        assert_eq!((from, out.len()), (0, 0));
    }
}

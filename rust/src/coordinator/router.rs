//! The router: request → execution plan.
//!
//! Plan selection (`ExecMode::Auto`):
//! 1. an exact- or padded-size PJRT **core artifact** if any compiled
//!    (entry, D, M) variant has capacity ≥ T (tightest capacity wins —
//!    identity-element padding makes shorter sequences exact, see
//!    python/compile/model.py);
//! 2. otherwise, if block artifacts exist for (D, M), a **sharded** plan
//!    (paper §V-B): ⌈T/L⌉ blocks of the compiled block length L;
//! 3. otherwise the **native** library.
//!
//! Invariants (property-tested below): every plan covers the full
//! request; sharded block ranges partition [0, T); padding never exceeds
//! the chosen artifact's capacity.

use crate::blockwise::BlockPlan;
use crate::error::{Error, Result};
use crate::runtime::Manifest;

use super::request::{Algo, DecodeRequest, ExecMode};

/// Router tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Prefer sequential-entry artifacts below this T (tiny requests are
    /// dominated by dispatch, where the lax.scan artifact is leaner).
    pub seq_below: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { seq_below: 0 }
    }
}

/// A resolved execution plan.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecutionPlan {
    /// Run one core artifact, padding the sequence to its capacity.
    PjrtCore { artifact: String, capacity: usize },
    /// §V-B sharded execution over block artifacts.
    Sharded {
        fold_first: String,
        fold_mid: String,
        finalize_first: String,
        finalize_mid: String,
        block_len: usize,
        num_blocks: usize,
    },
    /// Native-Rust algorithm library.
    Native,
}

impl ExecutionPlan {
    /// Short human-readable tag for responses/metrics.
    pub fn describe(&self, t: usize) -> String {
        match self {
            ExecutionPlan::PjrtCore { artifact, capacity } => {
                format!("pjrt:{artifact} pad={}", capacity - t)
            }
            ExecutionPlan::Sharded { block_len, num_blocks, .. } => {
                format!("sharded:blocks={num_blocks} len={block_len}")
            }
            ExecutionPlan::Native => "native".to_string(),
        }
    }
}

/// Stateless planner over a manifest.
#[derive(Debug, Clone)]
pub struct Router {
    config: RouterConfig,
}

impl Router {
    /// A planner under `config`'s routing thresholds.
    pub fn new(config: RouterConfig) -> Self {
        Self { config }
    }

    /// Plan a request for a model with `d` states and `m` symbols.
    pub fn plan(
        &self,
        manifest: Option<&Manifest>,
        req: &DecodeRequest,
        d: usize,
        m: usize,
    ) -> Result<ExecutionPlan> {
        let t = req.ys.len();
        if t == 0 {
            return Err(Error::invalid_request("empty sequence"));
        }
        match req.mode {
            ExecMode::Native => Ok(ExecutionPlan::Native),
            ExecMode::Pjrt => {
                let manifest = manifest
                    .ok_or_else(|| Error::artifact("no artifacts loaded"))?;
                self.core_plan(manifest, req.algo, t, d, m).ok_or_else(|| {
                    Error::artifact(format!(
                        "no core artifact covers T={t} (entry {}, D={d}, M={m})",
                        req.algo.par_entry()
                    ))
                })
            }
            ExecMode::Sharded => {
                let manifest = manifest
                    .ok_or_else(|| Error::artifact("no artifacts loaded"))?;
                self.sharded_plan(manifest, req.algo, t, d, m).ok_or_else(|| {
                    Error::artifact(format!(
                        "no block artifacts for algo {:?} at D={d}, M={m}",
                        req.algo
                    ))
                })
            }
            ExecMode::Auto => {
                if let Some(manifest) = manifest {
                    if let Some(plan) = self.core_plan(manifest, req.algo, t, d, m) {
                        return Ok(plan);
                    }
                    if let Some(plan) = self.sharded_plan(manifest, req.algo, t, d, m)
                    {
                        return Ok(plan);
                    }
                }
                Ok(ExecutionPlan::Native)
            }
        }
    }

    /// Streaming observability: the core artifact that *could* serve a
    /// suffix window of `width` steps — the same smallest-covering
    /// lookup the decode planner uses, reused for the session appends'
    /// fixed-lag windows. Surfaced as a plan hint in `StreamReply`;
    /// execution today is always native (the XLA-backed suffix rescan
    /// is a ROADMAP open item).
    pub fn window_hint(
        &self,
        manifest: Option<&Manifest>,
        algo: Algo,
        width: usize,
        d: usize,
        m: usize,
    ) -> Option<String> {
        manifest?
            .smallest_covering(algo.par_entry(), width, d, m)
            .map(|spec| spec.name.clone())
    }

    fn core_plan(
        &self,
        manifest: &Manifest,
        algo: Algo,
        t: usize,
        d: usize,
        m: usize,
    ) -> Option<ExecutionPlan> {
        let entry = if t < self.config.seq_below {
            algo.seq_entry()
        } else {
            algo.par_entry()
        };
        let spec = manifest
            .smallest_covering(entry, t, d, m)
            .or_else(|| manifest.smallest_covering(algo.par_entry(), t, d, m))?;
        Some(ExecutionPlan::PjrtCore {
            artifact: spec.name.clone(),
            capacity: spec.t,
        })
    }

    fn sharded_plan(
        &self,
        manifest: &Manifest,
        algo: Algo,
        t: usize,
        d: usize,
        m: usize,
    ) -> Option<ExecutionPlan> {
        // BayesSmooth has no block decomposition compiled; SP covers it
        // numerically (identical marginals), so route it through SP. The
        // family prefix otherwise comes from the engine taxonomy.
        let family = match algo {
            Algo::BayesSmooth => "sp",
            other => other
                .parallel()
                .artifact_family()
                .expect("decode algorithms always have an artifact family"),
        };
        let fold_first = manifest.block(&format!("{family}_block_fold_first"), d, m)?;
        let fold_mid = manifest.block(&format!("{family}_block_fold_mid"), d, m)?;
        let fin_first =
            manifest.block(&format!("{family}_block_finalize_first"), d, m)?;
        let fin_mid = manifest.block(&format!("{family}_block_finalize_mid"), d, m)?;
        let block_len = fold_first.t;
        debug_assert_eq!(block_len, fold_mid.t);
        let plan = BlockPlan::new(t, block_len);
        Some(ExecutionPlan::Sharded {
            fold_first: fold_first.name.clone(),
            fold_mid: fold_mid.name.clone(),
            finalize_first: fin_first.name.clone(),
            finalize_mid: fin_mid.name.clone(),
            block_len,
            num_blocks: plan.num_blocks(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::DecodeRequest;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let json = r#"{
          "version": 1, "interchange": "hlo-text",
          "artifacts": [
            {"name": "sp_par_T128", "entry": "sp_par", "kind": "core",
             "t": 128, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
            {"name": "sp_par_T1024", "entry": "sp_par", "kind": "core",
             "t": 1024, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
            {"name": "mp_par_T128", "entry": "mp_par", "kind": "core",
             "t": 128, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
            {"name": "ff", "entry": "sp_block_fold_first", "kind": "block",
             "t": 256, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
            {"name": "fm", "entry": "sp_block_fold_mid", "kind": "block",
             "t": 256, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
            {"name": "zf", "entry": "sp_block_finalize_first", "kind": "block",
             "t": 256, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
            {"name": "zm", "entry": "sp_block_finalize_mid", "kind": "block",
             "t": 256, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []}
          ]
        }"#;
        Manifest::parse(json, PathBuf::from("/x")).unwrap()
    }

    fn req(t: usize, algo: Algo) -> DecodeRequest {
        DecodeRequest::new(1, "ge", vec![0; t], algo)
    }

    #[test]
    fn picks_tightest_core_artifact() {
        let m = manifest();
        let r = Router::new(RouterConfig::default());
        let plan = r.plan(Some(&m), &req(100, Algo::Smooth), 4, 2).unwrap();
        assert_eq!(
            plan,
            ExecutionPlan::PjrtCore { artifact: "sp_par_T128".into(), capacity: 128 }
        );
        let plan = r.plan(Some(&m), &req(129, Algo::Smooth), 4, 2).unwrap();
        assert_eq!(
            plan,
            ExecutionPlan::PjrtCore { artifact: "sp_par_T1024".into(), capacity: 1024 }
        );
    }

    #[test]
    fn shards_beyond_largest_artifact() {
        let m = manifest();
        let r = Router::new(RouterConfig::default());
        let plan = r.plan(Some(&m), &req(5000, Algo::Smooth), 4, 2).unwrap();
        match plan {
            ExecutionPlan::Sharded { block_len, num_blocks, .. } => {
                assert_eq!(block_len, 256);
                assert_eq!(num_blocks, 5000usize.div_ceil(256));
            }
            other => panic!("expected sharded, got {other:?}"),
        }
    }

    #[test]
    fn falls_back_to_native() {
        let m = manifest();
        let r = Router::new(RouterConfig::default());
        // MAP has no block artifacts in this manifest and T exceeds the
        // only mp core artifact.
        let plan = r.plan(Some(&m), &req(5000, Algo::Map), 4, 2).unwrap();
        assert_eq!(plan, ExecutionPlan::Native);
        // No manifest at all.
        let plan = r.plan(None, &req(10, Algo::Smooth), 4, 2).unwrap();
        assert_eq!(plan, ExecutionPlan::Native);
        // Wrong dimensions.
        let plan = r.plan(Some(&m), &req(10, Algo::Smooth), 8, 2).unwrap();
        assert_eq!(plan, ExecutionPlan::Native);
    }

    #[test]
    fn forced_modes() {
        let m = manifest();
        let r = Router::new(RouterConfig::default());
        let plan = r
            .plan(Some(&m), &req(10, Algo::Smooth).with_mode(ExecMode::Native), 4, 2)
            .unwrap();
        assert_eq!(plan, ExecutionPlan::Native);
        assert!(r
            .plan(Some(&m), &req(5000, Algo::Smooth).with_mode(ExecMode::Pjrt), 4, 2)
            .is_err());
        assert!(r
            .plan(Some(&m), &req(50, Algo::Map).with_mode(ExecMode::Sharded), 4, 2)
            .is_err());
        assert!(r
            .plan(None, &req(50, Algo::Smooth).with_mode(ExecMode::Pjrt), 4, 2)
            .is_err());
    }

    #[test]
    fn rejects_empty() {
        let r = Router::new(RouterConfig::default());
        assert!(r.plan(None, &req(0, Algo::Smooth), 4, 2).is_err());
    }

    #[test]
    fn window_hint_reuses_core_lookup() {
        let m = manifest();
        let r = Router::new(RouterConfig::default());
        assert_eq!(
            r.window_hint(Some(&m), Algo::Smooth, 64, 4, 2),
            Some("sp_par_T128".to_string())
        );
        assert_eq!(
            r.window_hint(Some(&m), Algo::Smooth, 500, 4, 2),
            Some("sp_par_T1024".to_string())
        );
        // Beyond every core artifact, with no manifest, or for wrong
        // dimensions there is no hint.
        assert_eq!(r.window_hint(Some(&m), Algo::Smooth, 5000, 4, 2), None);
        assert_eq!(r.window_hint(None, Algo::Smooth, 64, 4, 2), None);
        assert_eq!(r.window_hint(Some(&m), Algo::Smooth, 64, 8, 2), None);
    }

    #[test]
    fn plan_always_covers_request_property() {
        let m = manifest();
        let r = Router::new(RouterConfig::default());
        let mut runner = crate::proptestx::Runner::new("router-covers");
        runner.run(200, |rng| {
            let t = 1 + rng.below(20_000) as usize;
            let algo = match rng.below(3) {
                0 => Algo::Smooth,
                1 => Algo::Map,
                _ => Algo::BayesSmooth,
            };
            let plan = r.plan(Some(&m), &req(t, algo), 4, 2).unwrap();
            match plan {
                ExecutionPlan::PjrtCore { capacity, .. } => assert!(capacity >= t),
                ExecutionPlan::Sharded { block_len, num_blocks, .. } => {
                    assert!(block_len * num_blocks >= t);
                    assert!(block_len * (num_blocks - 1) < t, "no empty blocks");
                    let bp = crate::blockwise::BlockPlan::new(t, block_len);
                    assert!(bp.is_partition());
                }
                ExecutionPlan::Native => {}
            }
        });
    }

    #[test]
    fn describe_strings() {
        let p = ExecutionPlan::PjrtCore { artifact: "x".into(), capacity: 128 };
        assert_eq!(p.describe(100), "pjrt:x pad=28");
        assert_eq!(ExecutionPlan::Native.describe(5), "native");
    }
}

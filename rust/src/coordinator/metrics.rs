//! Serving metrics: throughput counters, latency histogram, queue gauges.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Log-scaled latency histogram (microseconds, ~2 buckets per decade)
/// plus counters. All methods are thread-safe; snapshots are consistent
/// enough for reporting (counters are monotone).
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    sharded_blocks: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub sharded_blocks: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl MetricsSnapshot {
    /// Mean batch occupancy (items per dispatched batch).
    pub fn batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_complete(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us
            .lock()
            .unwrap()
            .push(latency.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    pub fn on_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    pub fn on_sharded_blocks(&self, blocks: usize) {
        self.sharded_blocks.fetch_add(blocks as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap().clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                let idx = ((lat.len() as f64 - 1.0) * p).floor() as usize;
                lat[idx]
            }
        };
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            sharded_blocks: self.sharded_blocks.load(Ordering::Relaxed),
            p50_us: pct(0.50),
            p99_us: pct(0.99),
            max_us: lat.last().copied().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.on_request();
            m.on_complete(Duration::from_micros(i));
        }
        m.on_failure();
        m.on_batch(4);
        m.on_batch(8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.completed, 100);
        assert_eq!(s.failed, 1);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.max_us, 100);
        assert!((s.batch_occupancy() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.batch_occupancy(), 0.0);
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|sc| {
            for _ in 0..8 {
                let m = std::sync::Arc::clone(&m);
                sc.spawn(move || {
                    for _ in 0..1000 {
                        m.on_request();
                        m.on_complete(Duration::from_micros(5));
                    }
                });
            }
        });
        let s = m.snapshot();
        assert_eq!(s.requests, 8000);
        assert_eq!(s.completed, 8000);
    }
}

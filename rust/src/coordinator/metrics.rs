//! Serving metrics: throughput counters, latency histogram, queue gauges.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::obs::Timeline;

/// Cap on retained latency samples: percentiles are computed over the
/// most recent window (ring overwrite), so a long-lived server's memory
/// and snapshot cost stay bounded no matter how many requests it serves.
const MAX_LATENCY_SAMPLES: usize = 4096;

/// Bounded latency sample store: grows to [`MAX_LATENCY_SAMPLES`], then
/// overwrites the oldest sample.
#[derive(Debug, Default)]
struct SampleWindow {
    samples: Vec<u64>,
    next: usize,
}

impl SampleWindow {
    fn push(&mut self, v: u64) {
        if self.samples.len() < MAX_LATENCY_SAMPLES {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
            self.next = (self.next + 1) % MAX_LATENCY_SAMPLES;
        }
    }
}

/// Upper bounds (µs) of the cumulative wire-latency histogram: the
/// distinct integer roundings of √2ᵏ — two buckets per octave — from
/// 1µs to 2³²µs (~71 minutes). A sample lands in the first bucket whose
/// bound is ≥ the sample (bounds are inclusive); anything past the last
/// bound lands in a separate overflow slot.
const HIST_BOUNDS: [u64; 64] = [
    1, 2, 3, 4, 6, 8, 11, 16, 23, 32, 45, 64, 91, 128, 181, 256, 362, 512,
    724, 1024, 1448, 2048, 2896, 4096, 5793, 8192, 11_585, 16_384, 23_170,
    32_768, 46_341, 65_536, 92_682, 131_072, 185_364, 262_144, 370_728,
    524_288, 741_455, 1_048_576, 1_482_910, 2_097_152, 2_965_821, 4_194_304,
    5_931_642, 8_388_608, 11_863_283, 16_777_216, 23_726_566, 33_554_432,
    47_453_133, 67_108_864, 94_906_266, 134_217_728, 189_812_531,
    268_435_456, 379_625_062, 536_870_912, 759_250_125, 1_073_741_824,
    1_518_500_250, 2_147_483_648, 3_037_000_499, 4_294_967_296,
];

/// Fixed verb slots for the per-verb wire histograms, ascending (the
/// scrape renders them in this order). Covers every verb the net layer
/// times today; a verb not in the table shares the trailing `stream`
/// slot rather than being dropped.
const WIRE_VERBS: [&str; 12] = [
    "append", "close", "decode", "export", "import", "open", "open_at",
    "ping", "release", "scrape", "stat", "stream",
];

/// Cumulative log-bucketed latency histogram: one counter per
/// [`HIST_BOUNDS`] bound plus an overflow slot, and an exact running
/// maximum. Recording is lock-free (one relaxed increment plus a
/// relaxed `fetch_max`) and the store is O(1) regardless of volume, so
/// unlike [`SampleWindow`] it never forgets an early outlier —
/// percentiles are instead quantized up to the bucket's bound (at most
/// a √2 overestimate).
#[derive(Debug)]
struct LatencyHistogram {
    buckets: [AtomicU64; HIST_BOUNDS.len() + 1],
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    // Manual: `[T; 65]` has no derived `Default` (std stops at 32).
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Record one latency sample, µs.
    fn record(&self, us: u64) {
        let idx = HIST_BOUNDS.partition_point(|&b| b < us);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Total samples recorded (sum over every bucket).
    fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Nearest-rank percentile over loaded bucket `counts` (64 bounds +
    /// overflow): the upper bound of the bucket holding the sample at
    /// rank `floor((n-1)·p)+1` — the same rank the old sample-window
    /// `pct` picked — or the exact maximum when that rank falls in the
    /// overflow bucket.
    fn percentile(counts: &[u64], max_us: u64, p: f64) -> u64 {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((total - 1) as f64 * p).floor() as u64 + 1;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return HIST_BOUNDS.get(i).copied().unwrap_or(max_us);
            }
        }
        max_us
    }

    /// Snapshot this histogram into the per-verb stats view.
    fn stats(&self, verb: &str) -> WireVerbStats {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let max_us = self.max_us.load(Ordering::Relaxed);
        let mut cum = 0u64;
        let mut buckets = Vec::new();
        for (i, &c) in counts[..HIST_BOUNDS.len()].iter().enumerate() {
            cum += c;
            if c > 0 {
                buckets.push((HIST_BOUNDS[i], cum));
            }
        }
        WireVerbStats {
            verb: verb.to_string(),
            count: counts.iter().sum(),
            p50_us: Self::percentile(&counts, max_us, 0.50),
            p99_us: Self::percentile(&counts, max_us, 0.99),
            max_us,
            buckets,
        }
    }
}

/// Log-scaled latency histogram (microseconds, ~2 buckets per decade)
/// plus counters. All methods are thread-safe; snapshots are consistent
/// enough for reporting (counters are monotone; decode/append/restore
/// percentiles cover the most recent [`MAX_LATENCY_SAMPLES`] samples,
/// while the per-verb wire percentiles come from cumulative
/// [`LatencyHistogram`]s and cover the process lifetime).
///
/// The `sessions_* / append* / suffix_*` family instruments the
/// streaming path: per-append latency and the width of the forward
/// suffix rescan each fixed-lag query performed (bounded by lag + block
/// — the histogram makes a mis-pinned block visible immediately).
/// Suffix widths are bucketed at insert time (power-of-two upper
/// bounds), so that store is O(distinct buckets) regardless of volume.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    sharded_blocks: AtomicU64,
    latencies_us: Mutex<SampleWindow>,
    sessions_opened: AtomicU64,
    sessions_closed: AtomicU64,
    appends: AtomicU64,
    appended_obs: AtomicU64,
    append_latencies_us: Mutex<SampleWindow>,
    suffix_widths: Mutex<BTreeMap<u64, u64>>,
    spills: AtomicU64,
    restores: AtomicU64,
    sessions_recovered: AtomicU64,
    restore_latencies_us: Mutex<SampleWindow>,
    hk_enqueued: AtomicU64,
    hk_completed: AtomicU64,
    sync_batches: AtomicU64,
    sync_files: AtomicU64,
    synced_appends: AtomicU64,
    recovery_scans: AtomicU64,
    recovery_scan_us: AtomicU64,
    conns_opened: AtomicU64,
    conns_closed: AtomicU64,
    conns_refused: AtomicU64,
    wire_inflight: AtomicU64,
    /// Per-verb wire serving latency: one lock-free cumulative
    /// histogram per [`WIRE_VERBS`] slot (decode / open / append / stat
    /// / close / ...), index-aligned with that table.
    wire_verbs: [LatencyHistogram; WIRE_VERBS.len()],
    /// Event timeline whose health gauges snapshots surface (attached
    /// by the owning coordinator or router when one is configured).
    timeline: Mutex<Option<Arc<Timeline>>>,
    sessions_placed: AtomicU64,
    sessions_migrated: AtomicU64,
    decode_failovers: AtomicU64,
    rejects_sent: AtomicU64,
    deadline_sheds: AtomicU64,
    quota_sheds: AtomicU64,
    /// Per-worker router-side wire latency (cluster tier): call count
    /// plus a bounded sample window, keyed by the worker's address.
    worker_links: Mutex<BTreeMap<String, (u64, SampleWindow)>>,
}

/// Per-verb wire latency derived from the cumulative log-bucketed
/// histogram (see [`MetricsSnapshot::wire_verbs`]). Percentiles are
/// quantized up to the holding bucket's bound — at most a √2
/// overestimate — and cover every request ever served, not a recent
/// window; the maximum is exact.
#[derive(Debug, Clone, PartialEq)]
pub struct WireVerbStats {
    /// Verb name ("decode", "open", "append", "stat", "close", ...).
    pub verb: String,
    /// Requests of this verb served over the wire.
    pub count: u64,
    /// Median wire serving latency, µs (bucket upper bound).
    pub p50_us: u64,
    /// 99th-percentile wire serving latency, µs (bucket upper bound).
    pub p99_us: u64,
    /// Maximum wire serving latency, µs (exact).
    pub max_us: u64,
    /// Cumulative histogram: `(upper bound µs, samples ≤ bound)`,
    /// ascending, bounds whose own bucket is empty omitted. Samples
    /// past the last bound show up only in `count` (the scrape's
    /// `le_inf` line).
    pub buckets: Vec<(u64, u64)>,
}

/// Per-worker router→worker wire latency percentiles over the retained
/// sample window (see [`MetricsSnapshot::worker_links`]). One entry per
/// worker address the cluster router has spoken to.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerLinkStats {
    /// Worker address (`host:port`) as configured on the router.
    pub worker: String,
    /// Wire calls the router has completed against this worker.
    pub count: u64,
    /// Median router→worker wire latency over the window, µs.
    pub p50_us: u64,
    /// 99th-percentile router→worker wire latency over the window, µs.
    pub p99_us: u64,
    /// Maximum router→worker wire latency over the window, µs.
    pub max_us: u64,
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Decode requests received.
    pub requests: u64,
    /// Decode requests completed successfully.
    pub completed: u64,
    /// Requests (decode or stream) that returned an error.
    pub failed: u64,
    /// Batches dispatched by the decode batcher.
    pub batches: u64,
    /// Requests carried across all dispatched batches.
    pub batched_items: u64,
    /// Blocks executed by sharded (§V-B) plans.
    pub sharded_blocks: u64,
    /// Median decode latency over the retained window, µs.
    pub p50_us: u64,
    /// 99th-percentile decode latency over the retained window, µs.
    pub p99_us: u64,
    /// Maximum decode latency over the retained window, µs.
    pub max_us: u64,
    /// Streaming sessions opened.
    pub sessions_opened: u64,
    /// Streaming sessions closed.
    pub sessions_closed: u64,
    /// Append verbs served.
    pub appends: u64,
    /// Observations carried across all appends.
    pub appended_obs: u64,
    /// Median append latency over the retained window, µs.
    pub append_p50_us: u64,
    /// 99th-percentile append latency over the retained window, µs.
    pub append_p99_us: u64,
    /// Maximum append latency over the retained window, µs.
    pub append_max_us: u64,
    /// Suffix-rescan width histogram: (power-of-two upper bound, count),
    /// ascending, empty buckets omitted.
    pub suffix_width_hist: Vec<(u64, u64)>,
    /// Sessions demoted to the store (resident chain dropped).
    pub spills: u64,
    /// Evicted sessions transparently restored on touch.
    pub restores: u64,
    /// Sessions re-registered from the store at startup.
    pub sessions_recovered: u64,
    /// Median transparent-restore latency over the window, µs.
    pub restore_p50_us: u64,
    /// 99th-percentile transparent-restore latency, µs.
    pub restore_p99_us: u64,
    /// Maximum transparent-restore latency, µs.
    pub restore_max_us: u64,
    /// Housekeeping tasks handed to the background worker so far.
    pub hk_enqueued: u64,
    /// Housekeeping tasks the background worker has finished.
    pub hk_completed: u64,
    /// Tasks currently waiting in (or running on) the housekeeping
    /// worker — the bounded-queue depth gauge.
    pub hk_queue_depth: u64,
    /// Completed group-commit sync batches (each one deadline window).
    pub sync_batches: u64,
    /// fsync syscalls those batches issued (one per dirty log).
    pub sync_files: u64,
    /// Append records acked across all completed sync batches.
    pub synced_appends: u64,
    /// Recovery scans run (`Coordinator::recover_sessions` calls).
    pub recovery_scans: u64,
    /// Wall time of the most recent recovery scan, µs — the gauge the
    /// metadata-only recovery path keeps near-zero even for stores with
    /// gigabytes of logged observations.
    pub recovery_scan_us: u64,
    /// TCP connections accepted by the network layer.
    pub conns_opened: u64,
    /// TCP connections that have since closed.
    pub conns_closed: u64,
    /// Connections refused (over `max_connections`, or while draining).
    pub conns_refused: u64,
    /// Gauge: connections open right now (`opened - closed`).
    pub open_conns: u64,
    /// Gauge: wire requests dispatched but not yet answered across all
    /// connections.
    pub wire_inflight: u64,
    /// Per-verb wire serving latency (request-decoded → response
    /// queued), ascending by verb name.
    pub wire_verbs: Vec<WireVerbStats>,
    /// Sessions the cluster router placed on a worker.
    pub sessions_placed: u64,
    /// Sessions the cluster router live-migrated between workers.
    pub sessions_migrated: u64,
    /// Decode requests the router re-routed after a worker failure.
    pub decode_failovers: u64,
    /// Reject (busy) frames sent to clients instead of serving.
    pub rejects_sent: u64,
    /// Requests shed because they would have started past their
    /// client-declared `deadline_ms` (a subset of `rejects_sent`).
    pub deadline_sheds: u64,
    /// Requests shed by the per-connection in-flight quota (a subset of
    /// `rejects_sent`).
    pub quota_sheds: u64,
    /// Sequence number of the last durably written timeline event (0
    /// when no timeline is attached).
    pub timeline_seq: u64,
    /// Timeline events dropped because the bounded emit channel was
    /// full — the overload signal for the observability pipeline
    /// itself, previously visible only on replay.
    pub timeline_dropped: u64,
    /// Timeline segment files on disk (0 when no timeline is attached).
    pub timeline_segments: u64,
    /// Per-worker router→worker wire latency, ascending by address.
    pub worker_links: Vec<WorkerLinkStats>,
    /// Process-wide linear-algebra kernel dispatch counters (specialized
    /// microkernel hits per shape, generic fallbacks, batched SoA
    /// sweeps). Unlike the serving counters above these are global to
    /// the process, not per-[`Metrics`] instance: every coordinator in
    /// the process reports the same kernel totals.
    pub kernels: crate::linalg::kernels::KernelStatsSnapshot,
}

impl MetricsSnapshot {
    /// Mean batch occupancy (items per dispatched batch).
    pub fn batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Mean observations per append.
    pub fn append_occupancy(&self) -> f64 {
        if self.appends == 0 {
            0.0
        } else {
            self.appended_obs as f64 / self.appends as f64
        }
    }

    /// Mean append records acked per group-commit sync batch — the
    /// amortization factor the deadline window buys (1.0 means every
    /// append paid its own fsync).
    pub fn sync_batch_occupancy(&self) -> f64 {
        if self.sync_batches == 0 {
            0.0
        } else {
            self.synced_appends as f64 / self.sync_batches as f64
        }
    }

    /// Render the full snapshot in the stable `key value` line format
    /// the wire scrape verb serves (`hmm-scan stat --connect ADDR`).
    ///
    /// One line per metric: a `[a-z0-9_]+` key, one space, a decimal
    /// value (integers for counters/gauges/percentiles, `{:.3}` floats
    /// for the occupancy ratios). Dynamic families embed their member
    /// in the key — `suffix_width_le_<bound>`, `wire_verb_<verb>_<stat>`,
    /// `wire_verb_<verb>_us_bucket_le_<bound>` (cumulative histogram
    /// lines, `le_inf` carrying the total), `worker_<address>_<stat>`
    /// (addresses sanitized to the key alphabet) — so the output stays
    /// line-oriented and
    /// `grep`/`awk`-parseable. Keys are append-only across releases:
    /// scrapers may rely on a present key keeping its meaning. The
    /// format is specified in `docs/OBSERVABILITY.md`.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut kv = |k: &str, v: u64| {
            let _ = writeln!(out, "{k} {v}");
        };
        kv("requests", self.requests);
        kv("completed", self.completed);
        kv("failed", self.failed);
        kv("batches", self.batches);
        kv("batched_items", self.batched_items);
        kv("sharded_blocks", self.sharded_blocks);
        kv("p50_us", self.p50_us);
        kv("p99_us", self.p99_us);
        kv("max_us", self.max_us);
        kv("sessions_opened", self.sessions_opened);
        kv("sessions_closed", self.sessions_closed);
        kv("appends", self.appends);
        kv("appended_obs", self.appended_obs);
        kv("append_p50_us", self.append_p50_us);
        kv("append_p99_us", self.append_p99_us);
        kv("append_max_us", self.append_max_us);
        kv("spills", self.spills);
        kv("restores", self.restores);
        kv("sessions_recovered", self.sessions_recovered);
        kv("restore_p50_us", self.restore_p50_us);
        kv("restore_p99_us", self.restore_p99_us);
        kv("restore_max_us", self.restore_max_us);
        kv("hk_enqueued", self.hk_enqueued);
        kv("hk_completed", self.hk_completed);
        kv("hk_queue_depth", self.hk_queue_depth);
        kv("sync_batches", self.sync_batches);
        kv("sync_files", self.sync_files);
        kv("synced_appends", self.synced_appends);
        kv("recovery_scans", self.recovery_scans);
        kv("recovery_scan_us", self.recovery_scan_us);
        kv("conns_opened", self.conns_opened);
        kv("conns_closed", self.conns_closed);
        kv("conns_refused", self.conns_refused);
        kv("open_conns", self.open_conns);
        kv("wire_inflight", self.wire_inflight);
        kv("sessions_placed", self.sessions_placed);
        kv("sessions_migrated", self.sessions_migrated);
        kv("decode_failovers", self.decode_failovers);
        kv("rejects_sent", self.rejects_sent);
        kv("deadline_sheds", self.deadline_sheds);
        kv("quota_sheds", self.quota_sheds);
        kv("timeline_seq", self.timeline_seq);
        kv("timeline_dropped", self.timeline_dropped);
        kv("timeline_segments", self.timeline_segments);
        kv("kernel_spec_d2", self.kernels.spec_d2);
        kv("kernel_spec_d4", self.kernels.spec_d4);
        kv("kernel_spec_d8", self.kernels.spec_d8);
        kv("kernel_spec_d16", self.kernels.spec_d16);
        kv("kernel_generic", self.kernels.generic);
        kv("kernel_batched_calls", self.kernels.batched_calls);
        kv("kernel_batched_lanes", self.kernels.batched_lanes);
        let _ = writeln!(out, "batch_occupancy {:.3}", self.batch_occupancy());
        let _ =
            writeln!(out, "append_occupancy {:.3}", self.append_occupancy());
        let _ = writeln!(
            out,
            "sync_batch_occupancy {:.3}",
            self.sync_batch_occupancy()
        );
        for (bound, count) in &self.suffix_width_hist {
            let _ = writeln!(out, "suffix_width_le_{bound} {count}");
        }
        for v in &self.wire_verbs {
            let verb = sanitize_key(&v.verb);
            let _ = writeln!(out, "wire_verb_{verb}_count {}", v.count);
            let _ = writeln!(out, "wire_verb_{verb}_p50_us {}", v.p50_us);
            let _ = writeln!(out, "wire_verb_{verb}_p99_us {}", v.p99_us);
            let _ = writeln!(out, "wire_verb_{verb}_max_us {}", v.max_us);
            for (bound, cum) in &v.buckets {
                let _ = writeln!(
                    out,
                    "wire_verb_{verb}_us_bucket_le_{bound} {cum}"
                );
            }
            let _ =
                writeln!(out, "wire_verb_{verb}_us_bucket_le_inf {}", v.count);
        }
        for w in &self.worker_links {
            let worker = sanitize_key(&w.worker);
            let _ = writeln!(out, "worker_{worker}_count {}", w.count);
            let _ = writeln!(out, "worker_{worker}_p50_us {}", w.p50_us);
            let _ = writeln!(out, "worker_{worker}_p99_us {}", w.p99_us);
            let _ = writeln!(out, "worker_{worker}_max_us {}", w.max_us);
        }
        out
    }
}

/// Map an arbitrary member name (a verb or a `host:port` worker
/// address) onto the scrape-key alphabet: lowercased ASCII
/// alphanumerics preserved, every other byte replaced by `_`.
fn sanitize_key(s: &str) -> String {
    s.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() {
                c.to_ascii_lowercase()
            } else {
                '_'
            }
        })
        .collect()
}

impl Metrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one decode request received.
    pub fn on_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one decode completing in `latency`.
    pub fn on_complete(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us
            .lock()
            .unwrap()
            .push(latency.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Record one failed request (decode or stream verb).
    pub fn on_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `items` requests.
    pub fn on_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Record `blocks` blocks executed by a sharded plan.
    pub fn on_sharded_blocks(&self, blocks: usize) {
        self.sharded_blocks.fetch_add(blocks as u64, Ordering::Relaxed);
    }

    /// Record one streaming session opened.
    pub fn on_session_open(&self) {
        self.sessions_opened.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one streaming session closed.
    pub fn on_session_close(&self) {
        self.sessions_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one append of `obs` observations taking `latency`.
    pub fn on_append(&self, obs: usize, latency: Duration) {
        self.appends.fetch_add(1, Ordering::Relaxed);
        self.appended_obs.fetch_add(obs as u64, Ordering::Relaxed);
        self.append_latencies_us
            .lock()
            .unwrap()
            .push(latency.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Record one session demotion to the store.
    pub fn on_spill(&self) {
        self.spills.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one transparent restore taking `latency` (store read +
    /// resume + append replay — the eviction tax the histogram makes
    /// visible).
    pub fn on_restore(&self, latency: Duration) {
        self.restores.fetch_add(1, Ordering::Relaxed);
        self.restore_latencies_us
            .lock()
            .unwrap()
            .push(latency.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Record `n` sessions re-registered from the store at startup.
    pub fn on_recovered(&self, n: usize) {
        self.sessions_recovered.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record one task handed to the housekeeping worker.
    pub fn on_hk_enqueued(&self) {
        self.hk_enqueued.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one housekeeping task finished by the worker.
    pub fn on_hk_completed(&self) {
        self.hk_completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one completed group-commit batch: `files` fsyncs covering
    /// `records` acked append records.
    pub fn on_sync_batch(&self, files: usize, records: usize) {
        self.sync_batches.fetch_add(1, Ordering::Relaxed);
        self.sync_files.fetch_add(files as u64, Ordering::Relaxed);
        self.synced_appends.fetch_add(records as u64, Ordering::Relaxed);
    }

    /// Record one recovery scan taking `elapsed` (the metadata walk of
    /// `Coordinator::recover_sessions`).
    pub fn on_recovery_scan(&self, elapsed: Duration) {
        self.recovery_scans.fetch_add(1, Ordering::Relaxed);
        self.recovery_scan_us.store(
            elapsed.as_micros().min(u128::from(u64::MAX)) as u64,
            Ordering::Relaxed,
        );
    }

    /// Record one TCP connection accepted.
    pub fn on_conn_open(&self) {
        self.conns_opened.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one TCP connection closed.
    pub fn on_conn_close(&self) {
        self.conns_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one TCP connection refused (capacity or drain).
    pub fn on_conn_refused(&self) {
        self.conns_refused.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one wire request dispatched (pairs with
    /// [`on_wire_done`](Self::on_wire_done) — the difference is the
    /// in-flight gauge).
    pub fn on_wire_start(&self) {
        self.wire_inflight.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one wire request answered: `verb` serving latency from
    /// frame decoded to response queued, added lock-free to that verb's
    /// cumulative histogram (verbs outside [`WIRE_VERBS`] share the
    /// `stream` slot).
    pub fn on_wire_done(&self, verb: &'static str, latency: Duration) {
        // Guard against unpaired calls: the gauge must never wrap.
        let _ = self.wire_inflight.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| v.checked_sub(1),
        );
        let idx = WIRE_VERBS
            .binary_search(&verb)
            .unwrap_or(WIRE_VERBS.len() - 1);
        self.wire_verbs[idx]
            .record(latency.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Attach the event timeline whose health gauges (`timeline_seq`,
    /// `timeline_dropped`, `timeline_segments`) snapshots should
    /// surface — silent event drops become a scrapeable counter
    /// instead of a post-hoc replay surprise.
    pub fn attach_timeline(&self, timeline: Arc<Timeline>) {
        *self.timeline.lock().unwrap() = Some(timeline);
    }

    /// Record one session placed on a worker by the cluster router.
    pub fn on_session_placed(&self) {
        self.sessions_placed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one session live-migrated between workers.
    pub fn on_session_migrated(&self) {
        self.sessions_migrated.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one decode re-routed to another worker after a failure.
    pub fn on_failover(&self) {
        self.decode_failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one reject (busy) frame sent instead of serving.
    pub fn on_reject(&self) {
        self.rejects_sent.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request shed because it would have started past its
    /// client-declared deadline (counted alongside
    /// [`on_reject`](Self::on_reject), which the reject path also
    /// records).
    pub fn on_deadline_shed(&self) {
        self.deadline_sheds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request shed by the per-connection in-flight quota.
    pub fn on_quota_shed(&self) {
        self.quota_sheds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one completed router→worker wire call against `worker`
    /// taking `latency` (the cluster tier's per-worker link histogram).
    pub fn on_worker_call(&self, worker: &str, latency: Duration) {
        let mut links = self.worker_links.lock().unwrap();
        let entry = links.entry(worker.to_string()).or_default();
        entry.0 += 1;
        entry.1.push(latency.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Record the forward suffix-rescan width of a fixed-lag query
    /// (bucketed immediately — power-of-two upper bound).
    pub fn on_suffix_width(&self, width: usize) {
        *self
            .suffix_widths
            .lock()
            .unwrap()
            .entry((width as u64).max(1).next_power_of_two())
            .or_default() += 1;
    }

    /// Point-in-time copy of every counter, gauge and percentile.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap().samples.clone();
        lat.sort_unstable();
        let mut app = self.append_latencies_us.lock().unwrap().samples.clone();
        app.sort_unstable();
        let mut res = self.restore_latencies_us.lock().unwrap().samples.clone();
        res.sort_unstable();
        let pct = |sorted: &[u64], p: f64| -> u64 {
            if sorted.is_empty() {
                0
            } else {
                let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
                sorted[idx]
            }
        };
        let hist = self.suffix_widths.lock().unwrap().clone();
        // Only verbs that have actually served a request appear, so a
        // pure decode server doesn't scrape eleven all-zero families.
        let wire_verbs: Vec<WireVerbStats> = WIRE_VERBS
            .iter()
            .zip(self.wire_verbs.iter())
            .filter(|(_, h)| h.count() > 0)
            .map(|(verb, h)| h.stats(verb))
            .collect();
        let (timeline_seq, timeline_dropped, timeline_segments) =
            match self.timeline.lock().unwrap().as_ref() {
                Some(tl) => (tl.last_seq(), tl.dropped(), tl.segments()),
                None => (0, 0, 0),
            };
        let worker_links: Vec<WorkerLinkStats> = self
            .worker_links
            .lock()
            .unwrap()
            .iter()
            .map(|(worker, (count, window))| {
                let mut lat = window.samples.clone();
                lat.sort_unstable();
                WorkerLinkStats {
                    worker: worker.clone(),
                    count: *count,
                    p50_us: pct(&lat, 0.50),
                    p99_us: pct(&lat, 0.99),
                    max_us: lat.last().copied().unwrap_or(0),
                }
            })
            .collect();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            sharded_blocks: self.sharded_blocks.load(Ordering::Relaxed),
            p50_us: pct(&lat, 0.50),
            p99_us: pct(&lat, 0.99),
            max_us: lat.last().copied().unwrap_or(0),
            sessions_opened: self.sessions_opened.load(Ordering::Relaxed),
            sessions_closed: self.sessions_closed.load(Ordering::Relaxed),
            appends: self.appends.load(Ordering::Relaxed),
            appended_obs: self.appended_obs.load(Ordering::Relaxed),
            append_p50_us: pct(&app, 0.50),
            append_p99_us: pct(&app, 0.99),
            append_max_us: app.last().copied().unwrap_or(0),
            suffix_width_hist: hist.into_iter().collect(),
            spills: self.spills.load(Ordering::Relaxed),
            restores: self.restores.load(Ordering::Relaxed),
            sessions_recovered: self.sessions_recovered.load(Ordering::Relaxed),
            restore_p50_us: pct(&res, 0.50),
            restore_p99_us: pct(&res, 0.99),
            restore_max_us: res.last().copied().unwrap_or(0),
            hk_enqueued: self.hk_enqueued.load(Ordering::Relaxed),
            hk_completed: self.hk_completed.load(Ordering::Relaxed),
            hk_queue_depth: self
                .hk_enqueued
                .load(Ordering::Relaxed)
                .saturating_sub(self.hk_completed.load(Ordering::Relaxed)),
            sync_batches: self.sync_batches.load(Ordering::Relaxed),
            sync_files: self.sync_files.load(Ordering::Relaxed),
            synced_appends: self.synced_appends.load(Ordering::Relaxed),
            recovery_scans: self.recovery_scans.load(Ordering::Relaxed),
            recovery_scan_us: self.recovery_scan_us.load(Ordering::Relaxed),
            conns_opened: self.conns_opened.load(Ordering::Relaxed),
            conns_closed: self.conns_closed.load(Ordering::Relaxed),
            conns_refused: self.conns_refused.load(Ordering::Relaxed),
            open_conns: self
                .conns_opened
                .load(Ordering::Relaxed)
                .saturating_sub(self.conns_closed.load(Ordering::Relaxed)),
            wire_inflight: self.wire_inflight.load(Ordering::Relaxed),
            wire_verbs,
            sessions_placed: self.sessions_placed.load(Ordering::Relaxed),
            sessions_migrated: self.sessions_migrated.load(Ordering::Relaxed),
            decode_failovers: self.decode_failovers.load(Ordering::Relaxed),
            rejects_sent: self.rejects_sent.load(Ordering::Relaxed),
            deadline_sheds: self.deadline_sheds.load(Ordering::Relaxed),
            quota_sheds: self.quota_sheds.load(Ordering::Relaxed),
            timeline_seq,
            timeline_dropped,
            timeline_segments,
            worker_links,
            kernels: crate::linalg::kernels::kernel_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.on_request();
            m.on_complete(Duration::from_micros(i));
        }
        m.on_failure();
        m.on_batch(4);
        m.on_batch(8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.completed, 100);
        assert_eq!(s.failed, 1);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.max_us, 100);
        assert!((s.batch_occupancy() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.batch_occupancy(), 0.0);
        assert_eq!(s.append_p50_us, 0);
        assert_eq!(s.append_occupancy(), 0.0);
        assert!(s.suffix_width_hist.is_empty());
        assert_eq!((s.spills, s.restores, s.sessions_recovered), (0, 0, 0));
        assert_eq!(s.restore_p50_us, 0);
        assert_eq!((s.hk_enqueued, s.hk_completed, s.hk_queue_depth), (0, 0, 0));
        assert_eq!((s.sync_batches, s.sync_files, s.synced_appends), (0, 0, 0));
        assert_eq!(s.sync_batch_occupancy(), 0.0);
        assert_eq!((s.recovery_scans, s.recovery_scan_us), (0, 0));
    }

    #[test]
    fn housekeeping_sync_and_recovery_gauges() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.on_hk_enqueued();
        }
        for _ in 0..3 {
            m.on_hk_completed();
        }
        m.on_sync_batch(2, 9);
        m.on_sync_batch(1, 1);
        m.on_recovery_scan(Duration::from_micros(450));
        m.on_recovery_scan(Duration::from_micros(120));
        let s = m.snapshot();
        assert_eq!((s.hk_enqueued, s.hk_completed, s.hk_queue_depth), (5, 3, 2));
        assert_eq!((s.sync_batches, s.sync_files, s.synced_appends), (2, 3, 10));
        assert!((s.sync_batch_occupancy() - 5.0).abs() < 1e-12);
        assert_eq!(s.recovery_scans, 2);
        assert_eq!(s.recovery_scan_us, 120, "gauge holds the latest scan");
    }

    #[test]
    fn store_counters_and_restore_latency() {
        let m = Metrics::new();
        m.on_spill();
        m.on_spill();
        for i in 1..=4u64 {
            m.on_restore(Duration::from_micros(i * 100));
        }
        m.on_recovered(6);
        let s = m.snapshot();
        assert_eq!(s.spills, 2);
        assert_eq!(s.restores, 4);
        assert_eq!(s.sessions_recovered, 6);
        assert_eq!(s.restore_p50_us, 200);
        assert_eq!(s.restore_max_us, 400);
    }

    #[test]
    fn streaming_counters_and_width_histogram() {
        let m = Metrics::new();
        m.on_session_open();
        m.on_session_open();
        m.on_session_close();
        for i in 1..=10u64 {
            m.on_append(3, Duration::from_micros(i * 10));
        }
        for w in [1usize, 2, 3, 60, 64, 65, 100, 1000] {
            m.on_suffix_width(w);
        }
        let s = m.snapshot();
        assert_eq!(s.sessions_opened, 2);
        assert_eq!(s.sessions_closed, 1);
        assert_eq!(s.appends, 10);
        assert_eq!(s.appended_obs, 30);
        assert!((s.append_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(s.append_p50_us, 50);
        assert_eq!(s.append_max_us, 100);
        // Buckets: 1→1, 2→2 (w=2), 4→3, 64→{60,64}, 128→65&100, 1024→1000.
        assert_eq!(
            s.suffix_width_hist,
            vec![(1, 1), (2, 1), (4, 1), (64, 2), (128, 2), (1024, 1)]
        );
    }

    #[test]
    fn connection_and_wire_gauges() {
        let m = Metrics::new();
        m.on_conn_open();
        m.on_conn_open();
        m.on_conn_refused();
        m.on_conn_close();
        m.on_wire_start();
        m.on_wire_start();
        m.on_wire_done("decode", Duration::from_micros(120));
        for i in 1..=4u64 {
            m.on_wire_start();
            m.on_wire_done("append", Duration::from_micros(i * 10));
        }
        let s = m.snapshot();
        assert_eq!((s.conns_opened, s.conns_closed, s.conns_refused), (2, 1, 1));
        assert_eq!(s.open_conns, 1);
        assert_eq!(s.wire_inflight, 1, "one decode still in flight");
        assert_eq!(s.wire_verbs.len(), 2);
        let append = s.wire_verbs.iter().find(|v| v.verb == "append").unwrap();
        assert_eq!(append.count, 4);
        // Samples 10/20/30/40 land in buckets ≤11/23/32/45; percentiles
        // report the holding bucket's upper bound, the max is exact.
        assert_eq!(append.p50_us, 23);
        assert_eq!(append.p99_us, 32);
        assert_eq!(append.max_us, 40);
        assert_eq!(append.buckets, vec![(11, 1), (23, 2), (32, 3), (45, 4)]);
        let decode = s.wire_verbs.iter().find(|v| v.verb == "decode").unwrap();
        assert_eq!((decode.count, decode.max_us), (1, 120));
        assert_eq!(decode.p50_us, 128, "one sample: its bucket's bound");
        // Unpaired done calls clamp at zero instead of wrapping.
        m.on_wire_done("decode", Duration::ZERO);
        m.on_wire_done("decode", Duration::ZERO);
        assert_eq!(m.snapshot().wire_inflight, 0);
    }

    #[test]
    fn latency_histogram_buckets_and_percentiles() {
        // The percentile walk and partition_point both rely on the
        // bounds table being strictly ascending.
        assert!(HIST_BOUNDS.windows(2).all(|w| w[0] < w[1]));
        let h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.stats("x").p50_us, 0, "empty histogram reads zero");
        h.record(23);
        assert_eq!(h.stats("x").buckets, vec![(23, 1)], "bounds inclusive");
        let h = LatencyHistogram::default();
        h.record(0);
        h.record(1);
        h.record(5_000_000_000); // past the last bound → overflow slot
        h.record(6_000_000_000);
        let s = h.stats("x");
        assert_eq!(s.count, 4);
        assert_eq!(s.buckets, vec![(1, 2)], "overflow never gets a bound");
        assert_eq!(s.p50_us, 1);
        assert_eq!(s.p99_us, 6_000_000_000, "overflow reports the exact max");
        assert_eq!(s.max_us, 6_000_000_000);
    }

    #[test]
    fn unknown_wire_verbs_share_the_stream_slot() {
        let m = Metrics::new();
        m.on_wire_start();
        m.on_wire_done("somenewverb", Duration::from_micros(7));
        let s = m.snapshot();
        assert_eq!(s.wire_verbs.len(), 1);
        let v = s.wire_verbs.iter().find(|v| v.verb == "stream").unwrap();
        assert_eq!((v.count, v.p50_us, v.max_us), (1, 8, 7));
    }

    #[test]
    fn timeline_gauges_surface_and_move_after_forced_drops() {
        use crate::obs::{Timeline, TimelineEvent};
        let dir = crate::store::testutil::tempdir("metrics-tl");
        let tl = Timeline::open(&dir).unwrap();
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(
            (s.timeline_seq, s.timeline_dropped, s.timeline_segments),
            (0, 0, 0),
            "no timeline attached: gauges stay zero"
        );
        m.attach_timeline(Arc::clone(&tl));
        tl.record(TimelineEvent::ConnRefuse);
        tl.flush();
        let s = m.snapshot();
        assert_eq!((s.timeline_seq, s.timeline_dropped), (1, 0));
        assert_eq!(s.timeline_segments, 1);
        // Stall the writer and overrun the bounded channel: the drop
        // gauge must move and land on the scrape verbatim.
        let release = tl.stall();
        for _ in 0..5000 {
            tl.record(TimelineEvent::ConnRefuse);
        }
        drop(release);
        tl.flush();
        let s = m.snapshot();
        assert!(s.timeline_dropped > 0, "channel never filled");
        assert!(s.timeline_seq > 1, "surviving records advanced the seq");
        let text = s.render_text();
        for (key, want) in [
            ("timeline_seq", s.timeline_seq),
            ("timeline_dropped", s.timeline_dropped),
            ("timeline_segments", s.timeline_segments),
        ] {
            assert!(
                text.lines().any(|l| l == format!("{key} {want}")),
                "scrape missing {key} {want}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cluster_routing_gauges() {
        let m = Metrics::new();
        m.on_session_placed();
        m.on_session_placed();
        m.on_session_migrated();
        m.on_failover();
        m.on_reject();
        m.on_reject();
        m.on_reject();
        for i in 1..=4u64 {
            m.on_worker_call("127.0.0.1:9001", Duration::from_micros(i * 10));
        }
        m.on_worker_call("127.0.0.1:9002", Duration::from_micros(70));
        let s = m.snapshot();
        assert_eq!(s.sessions_placed, 2);
        assert_eq!(s.sessions_migrated, 1);
        assert_eq!(s.decode_failovers, 1);
        assert_eq!(s.rejects_sent, 3);
        assert_eq!(s.worker_links.len(), 2);
        let a = &s.worker_links[0];
        assert_eq!(a.worker, "127.0.0.1:9001");
        assert_eq!(a.count, 4);
        assert_eq!(a.p50_us, 20);
        assert_eq!(a.max_us, 40);
        let b = &s.worker_links[1];
        assert_eq!((b.worker.as_str(), b.count, b.max_us), ("127.0.0.1:9002", 1, 70));
        // Fresh metrics report empty cluster gauges.
        let empty = Metrics::new().snapshot();
        assert_eq!(empty.sessions_placed, 0);
        assert!(empty.worker_links.is_empty());
    }

    #[test]
    fn latency_window_is_bounded() {
        let m = Metrics::new();
        for i in 0..(MAX_LATENCY_SAMPLES + 500) {
            m.on_append(1, Duration::from_micros(i as u64));
        }
        assert_eq!(
            m.append_latencies_us.lock().unwrap().samples.len(),
            MAX_LATENCY_SAMPLES,
            "sample store must stop growing at the cap"
        );
        let s = m.snapshot();
        // Counters still see everything; percentiles cover the window.
        assert_eq!(s.appends, (MAX_LATENCY_SAMPLES + 500) as u64);
        assert!(s.append_max_us >= MAX_LATENCY_SAMPLES as u64);
    }

    #[test]
    fn sample_window_wrap_keeps_only_the_most_recent_window() {
        // Push one full window (values 0..MAX), then 500 more
        // (MAX..MAX+500): the ring must hold exactly the most recent
        // MAX values, i.e. 500..MAX+500, with the oldest overwritten.
        let mut w = SampleWindow::default();
        for v in 0..(MAX_LATENCY_SAMPLES + 500) as u64 {
            w.push(v);
        }
        assert_eq!(w.samples.len(), MAX_LATENCY_SAMPLES);
        assert_eq!(w.next, 500, "next points at the oldest surviving slot");
        let mut sorted = w.samples.clone();
        sorted.sort_unstable();
        let expect: Vec<u64> =
            (500..(MAX_LATENCY_SAMPLES + 500) as u64).collect();
        assert_eq!(sorted, expect, "window is exactly the last MAX pushes");
    }

    #[test]
    fn sample_window_wrap_edge_overwrites_slot_zero_first() {
        // The next-pointer wrap edge: after exactly MAX pushes `next`
        // is still 0, so push MAX+1 must overwrite slot 0 (the oldest
        // sample), and a full extra lap must land `next` back at 0.
        let mut w = SampleWindow::default();
        for v in 0..MAX_LATENCY_SAMPLES as u64 {
            w.push(v);
        }
        assert_eq!(w.next, 0);
        assert_eq!(w.samples[0], 0);
        w.push(777_777);
        assert_eq!(w.samples[0], 777_777, "slot 0 is overwritten first");
        assert_eq!(w.next, 1);
        assert_eq!(w.samples[1], 1, "slot 1 still holds the old value");
        for v in 0..(MAX_LATENCY_SAMPLES - 1) as u64 {
            w.push(v);
        }
        assert_eq!(w.next, 0, "a full lap wraps the pointer back to 0");
        assert_eq!(w.samples.len(), MAX_LATENCY_SAMPLES);
    }

    #[test]
    fn wrapped_percentiles_reflect_only_the_recent_window() {
        // Satellite regression: a huge early outlier must fall out of
        // the percentile window once MAX more samples displace it.
        let m = Metrics::new();
        m.on_append(1, Duration::from_micros(10_000_000));
        let s = m.snapshot();
        assert_eq!(s.append_max_us, 10_000_000);
        for _ in 0..MAX_LATENCY_SAMPLES {
            m.on_append(1, Duration::from_micros(50));
        }
        let s = m.snapshot();
        assert_eq!(
            s.append_max_us, 50,
            "the outlier was overwritten by the wrapped window"
        );
        assert_eq!(s.append_p50_us, 50);
        assert_eq!(s.appends, MAX_LATENCY_SAMPLES as u64 + 1);
    }

    #[test]
    fn shed_counters_and_scrape_rendering() {
        let m = Metrics::new();
        m.on_request();
        m.on_complete(Duration::from_micros(40));
        m.on_reject();
        m.on_deadline_shed();
        m.on_quota_shed();
        m.on_wire_start();
        m.on_wire_done("decode", Duration::from_micros(25));
        m.on_worker_call("127.0.0.1:9001", Duration::from_micros(30));
        m.on_suffix_width(3);
        let s = m.snapshot();
        assert_eq!((s.rejects_sent, s.deadline_sheds, s.quota_sheds), (1, 1, 1));
        let text = s.render_text();
        // Every line is `key value` over the scrape alphabet.
        for line in text.lines() {
            let (key, value) = line.split_once(' ').expect("key value");
            assert!(!key.is_empty());
            assert!(
                key.bytes().all(|b| b.is_ascii_lowercase()
                    || b.is_ascii_digit()
                    || b == b'_'),
                "bad key: {key}"
            );
            assert!(value.parse::<f64>().is_ok(), "bad value: {line}");
        }
        let get = |k: &str| -> String {
            text.lines()
                .find_map(|l| l.strip_prefix(&format!("{k} ")))
                .unwrap_or_else(|| panic!("missing key {k}"))
                .to_string()
        };
        assert_eq!(get("requests"), "1");
        assert_eq!(get("rejects_sent"), "1");
        assert_eq!(get("deadline_sheds"), "1");
        assert_eq!(get("quota_sheds"), "1");
        assert_eq!(get("wire_inflight"), "0");
        assert_eq!(get("wire_verb_decode_count"), "1");
        assert_eq!(get("wire_verb_decode_max_us"), "25");
        // Cumulative histogram lines: 25µs is ≤ the 32µs bound, and the
        // le_inf tail always equals the verb count.
        assert_eq!(get("wire_verb_decode_us_bucket_le_32"), "1");
        assert_eq!(get("wire_verb_decode_us_bucket_le_inf"), "1");
        // Timeline gauges render even with no timeline attached.
        assert_eq!(get("timeline_seq"), "0");
        assert_eq!(get("timeline_dropped"), "0");
        assert_eq!(get("timeline_segments"), "0");
        assert_eq!(get("worker_127_0_0_1_9001_count"), "1");
        assert_eq!(get("worker_127_0_0_1_9001_max_us"), "30");
        assert_eq!(get("suffix_width_le_4"), "1");
        assert_eq!(get("batch_occupancy"), "0.000");
    }

    #[test]
    fn kernel_counters_surface_and_are_monotone() {
        use crate::linalg::kernels::{set_kernels_enabled, toggle_guard};
        use crate::linalg::{matmul_into, Mat};
        use crate::semiring::Prob;
        let _guard = toggle_guard();
        set_kernels_enabled(true);
        let before = Metrics::new().snapshot().kernels;
        let a4 = Mat::from_vec(4, 4, (0..16).map(|i| 0.1 + i as f64).collect());
        let mut out4 = Mat::zeros(4, 4);
        matmul_into::<Prob>(&a4, &a4, &mut out4);
        let a3 = Mat::from_vec(3, 3, (0..9).map(|i| 0.1 + i as f64).collect());
        let mut out3 = Mat::zeros(3, 3);
        matmul_into::<Prob>(&a3, &a3, &mut out3);
        let after = Metrics::new().snapshot().kernels;
        assert!(after.spec_d4 >= before.spec_d4 + 1, "4x4 must hit the D=4 kernel");
        assert!(after.generic >= before.generic + 1, "3x3 must fall back to generic");
        let text = Metrics::new().snapshot().render_text();
        for key in [
            "kernel_spec_d2",
            "kernel_spec_d4",
            "kernel_spec_d8",
            "kernel_spec_d16",
            "kernel_generic",
            "kernel_batched_calls",
            "kernel_batched_lanes",
        ] {
            let found = text
                .lines()
                .any(|l| l.strip_prefix(key).is_some_and(|r| r.starts_with(' ')));
            assert!(found, "missing scrape key {key}");
        }
        set_kernels_enabled(true);
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|sc| {
            for _ in 0..8 {
                let m = std::sync::Arc::clone(&m);
                sc.spawn(move || {
                    for _ in 0..1000 {
                        m.on_request();
                        m.on_complete(Duration::from_micros(5));
                    }
                });
            }
        });
        let s = m.snapshot();
        assert_eq!(s.requests, 8000);
        assert_eq!(s.completed, 8000);
    }
}

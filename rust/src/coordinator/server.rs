//! The coordinator server: XLA worker pool, model registry, decode entry
//! points, and the channel-fed serve loop.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::engine::{Engine, EngineOutput, XlaBackend};
use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::runtime::{ArtifactExec, Manifest, Registry, Value};
use crate::scan::ScanOptions;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{Algo, DecodeRequest, DecodeResponse, DecodeResult};
use super::router::{ExecutionPlan, Router, RouterConfig};
use super::sharder::{self, ShardedArtifacts};

// ===========================================================================
// XLA worker pool
// ===========================================================================

struct Job {
    artifact: String,
    inputs: Vec<Value>,
    reply: mpsc::Sender<Result<Vec<Value>>>,
}

/// Pool of threads each owning a private PJRT client + executable cache
/// (`xla::PjRtClient` is `Rc`-based and cannot cross threads, so worker
/// isolation is per-thread by construction). Jobs are distributed over a
/// shared queue; per-worker caches converge to the hot artifact set.
pub struct XlaPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl XlaPool {
    pub fn new(dir: PathBuf, workers: usize) -> Result<Self> {
        // Validate the manifest once up front for a fast, typed failure.
        Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let dir = dir.clone();
                thread::Builder::new()
                    .name(format!("xla-worker-{i}"))
                    .spawn(move || {
                        let registry = Registry::open(dir);
                        loop {
                            let job = {
                                let guard = rx.lock().expect("xla queue poisoned");
                                guard.recv()
                            };
                            let Ok(job) = job else { break };
                            let result = match &registry {
                                Ok(reg) => reg
                                    .get(&job.artifact)
                                    .and_then(|exe| exe.run(&job.inputs)),
                                Err(e) => Err(Error::xla(format!(
                                    "worker init failed: {e}"
                                ))),
                            };
                            let _ = job.reply.send(result);
                        }
                    })
                    .expect("spawn xla worker")
            })
            .collect();
        Ok(Self { tx: Some(tx), workers })
    }

    /// Submit a job; returns the reply channel.
    pub fn submit(
        &self,
        artifact: &str,
        inputs: Vec<Value>,
    ) -> mpsc::Receiver<Result<Vec<Value>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Job { artifact: artifact.to_string(), inputs, reply })
            .expect("xla queue closed");
        rx
    }
}

impl Drop for XlaPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl ArtifactExec for XlaPool {
    fn run(&self, artifact: &str, inputs: Vec<Value>) -> Result<Vec<Value>> {
        self.submit(artifact, inputs)
            .recv()
            .map_err(|_| Error::coordinator("xla worker dropped reply"))?
    }

    fn run_many(&self, jobs: Vec<(String, Vec<Value>)>) -> Vec<Result<Vec<Value>>> {
        // Dispatch everything, then collect — folds/finalizes of a
        // sharded plan run genuinely concurrently across workers.
        let rxs: Vec<_> = jobs
            .into_iter()
            .map(|(a, i)| self.submit(&a, i))
            .collect();
        rxs.into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| Error::coordinator("xla worker dropped reply"))?
            })
            .collect()
    }
}

// ===========================================================================
// Coordinator
// ===========================================================================

/// Coordinator construction parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifacts directory; `None` disables PJRT (native-only serving).
    pub artifacts: Option<PathBuf>,
    /// XLA worker threads (each owns a PJRT client).
    pub xla_workers: usize,
    pub batcher: BatcherConfig,
    pub router: RouterConfig,
    /// Threading for the native algorithm library.
    pub scan: ScanOptions,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifacts: {
                let dir = crate::runtime::artifacts_dir();
                dir.join("manifest.json").exists().then_some(dir)
            },
            xla_workers: 4,
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            scan: ScanOptions::default(),
        }
    }
}

impl CoordinatorConfig {
    /// Native-only configuration (no artifacts required).
    pub fn native_only() -> Self {
        Self { artifacts: None, ..Default::default() }
    }
}

/// The inference service.
///
/// All native execution dispatches through one [`Engine`] per registered
/// model (serialized by a per-model mutex so the engine's scratch
/// workspace is reused across requests); the PJRT core-artifact path
/// dispatches through the engine's [`XlaBackend`].
pub struct Coordinator {
    manifest: Option<Arc<Manifest>>,
    pool: Option<Arc<XlaPool>>,
    xla: Option<XlaBackend>,
    router: Router,
    models: RwLock<BTreeMap<String, ModelEntry>>,
    metrics: Arc<Metrics>,
    scan: ScanOptions,
    batcher_config: BatcherConfig,
}

/// One registered model: the Hmm and its serving engine, paired in a
/// single map entry so a concurrent re-registration can never match a
/// new model with a stale engine (or vice versa).
#[derive(Clone)]
struct ModelEntry {
    hmm: Arc<Hmm>,
    engine: Arc<Mutex<Engine>>,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Result<Self> {
        let (manifest, pool) = match &config.artifacts {
            Some(dir) => {
                let manifest = Arc::new(Manifest::load(dir)?);
                let pool = Arc::new(XlaPool::new(dir.clone(), config.xla_workers)?);
                (Some(manifest), Some(pool))
            }
            None => (None, None),
        };
        let xla = match (&manifest, &pool) {
            (Some(m), Some(p)) => {
                let exec: Arc<dyn ArtifactExec + Send + Sync> = Arc::clone(p);
                Some(XlaBackend::new(exec, Arc::clone(m)))
            }
            _ => None,
        };
        Ok(Self {
            manifest,
            pool,
            xla,
            router: Router::new(config.router),
            models: RwLock::new(BTreeMap::new()),
            metrics: Arc::new(Metrics::new()),
            scan: config.scan,
            batcher_config: config.batcher,
        })
    }

    pub fn register_model(&self, id: impl Into<String>, hmm: Hmm) {
        let hmm = Arc::new(hmm);
        let engine = Engine::builder(Arc::clone(&hmm))
            .scan_options(self.scan)
            .build();
        let entry = ModelEntry { hmm, engine: Arc::new(Mutex::new(engine)) };
        self.models.write().unwrap().insert(id.into(), entry);
    }

    fn entry(&self, id: &str) -> Result<ModelEntry> {
        self.models
            .read()
            .unwrap()
            .get(id)
            .cloned()
            .ok_or_else(|| Error::invalid_request(format!("unknown model '{id}'")))
    }

    pub fn model(&self, id: &str) -> Result<Arc<Hmm>> {
        Ok(self.entry(id)?.hmm)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Resolve the plan a request would execute (exposed for tests/CLI).
    pub fn plan_for(&self, req: &DecodeRequest) -> Result<ExecutionPlan> {
        let hmm = self.model(&req.model)?;
        hmm.check_observations(&req.ys)?;
        self.router.plan(
            self.manifest.as_deref(),
            req,
            hmm.num_states(),
            hmm.num_symbols(),
        )
    }

    /// Serve one request synchronously.
    pub fn decode(&self, req: DecodeRequest) -> Result<DecodeResponse> {
        self.metrics.on_request();
        let start = Instant::now();
        let result = self.execute(&req);
        match result {
            Ok((result, plan)) => {
                let elapsed = start.elapsed();
                self.metrics.on_complete(elapsed);
                Ok(DecodeResponse { id: req.id, result, plan, elapsed })
            }
            Err(e) => {
                self.metrics.on_failure();
                Err(e)
            }
        }
    }

    /// Serve a group of requests through the batcher: requests that
    /// resolve to the same artifact are dispatched back-to-back so the
    /// XLA pool executes them concurrently.
    pub fn decode_many(
        &self,
        reqs: Vec<DecodeRequest>,
    ) -> Vec<Result<DecodeResponse>> {
        let mut batcher: Batcher<(usize, DecodeRequest)> =
            Batcher::new(self.batcher_config);
        let now = Instant::now();
        let mut batches = Vec::new();
        for (idx, req) in reqs.into_iter().enumerate() {
            let key = match self.plan_for(&req) {
                Ok(plan) => plan_key(&plan),
                Err(_) => "invalid".to_string(), // decode() reports the error
            };
            if let Some(b) = batcher.push(&key, (idx, req), now) {
                batches.push(b);
            }
        }
        batches.extend(batcher.flush_all());

        let mut out: Vec<Option<Result<DecodeResponse>>> = Vec::new();
        for batch in &batches {
            self.metrics.on_batch(batch.items.len());
            out.resize_with(
                out.len().max(batch.items.iter().map(|(i, _)| i + 1).max().unwrap_or(0)),
                || None,
            );
        }
        for batch in batches {
            for (idx, req) in batch.items {
                let resp = self.decode(req);
                if idx >= out.len() {
                    out.resize_with(idx + 1, || None);
                }
                out[idx] = Some(resp);
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(Error::coordinator("lost request"))))
            .collect()
    }

    fn execute(&self, req: &DecodeRequest) -> Result<(DecodeResult, String)> {
        // Fetch the model/engine pair once, atomically, so a concurrent
        // re-registration cannot switch models between plan and run.
        let entry = self.entry(&req.model)?;
        let hmm = entry.hmm;
        hmm.check_observations(&req.ys)?;
        let plan = self.router.plan(
            self.manifest.as_deref(),
            req,
            hmm.num_states(),
            hmm.num_symbols(),
        )?;
        let tag = plan.describe(req.ys.len());
        let result = match &plan {
            ExecutionPlan::Native => {
                let mut engine = entry.engine.lock().expect("engine mutex poisoned");
                decode_result_from(engine.run(req.algo.parallel(), &req.ys)?)?
            }
            ExecutionPlan::PjrtCore { artifact, capacity } => {
                self.run_pjrt_core(&hmm, req, artifact, *capacity)?
            }
            ExecutionPlan::Sharded {
                fold_first,
                fold_mid,
                finalize_first,
                finalize_mid,
                block_len,
                num_blocks,
            } => {
                self.metrics.on_sharded_blocks(*num_blocks);
                let arts = ShardedArtifacts {
                    fold_first: fold_first.clone(),
                    fold_mid: fold_mid.clone(),
                    finalize_first: finalize_first.clone(),
                    finalize_mid: finalize_mid.clone(),
                    block_len: *block_len,
                };
                let pool = self
                    .pool
                    .as_ref()
                    .ok_or_else(|| Error::coordinator("no xla pool"))?;
                match req.algo {
                    Algo::Map => {
                        let (est, _) =
                            sharder::mp_sharded(&**pool, &arts, &hmm, &req.ys)?;
                        DecodeResult::Map(est)
                    }
                    Algo::Smooth | Algo::BayesSmooth => {
                        let (post, _) =
                            sharder::sp_sharded(&**pool, &arts, &hmm, &req.ys)?;
                        DecodeResult::Posterior(post)
                    }
                }
            }
        };
        Ok((result, tag))
    }

    /// PJRT-core plan: dispatch through the engine's XLA backend, which
    /// owns the marshal/decode contract with the compiled artifacts.
    fn run_pjrt_core(
        &self,
        hmm: &Hmm,
        req: &DecodeRequest,
        artifact: &str,
        capacity: usize,
    ) -> Result<DecodeResult> {
        let xla = self
            .xla
            .as_ref()
            .ok_or_else(|| Error::coordinator("no xla backend"))?;
        decode_result_from(xla.run_artifact(
            hmm,
            req.algo.parallel(),
            &req.ys,
            artifact,
            capacity,
        )?)
    }

    /// Spawn the serve loop on its own thread; returns a submit handle.
    pub fn serve(self: Arc<Self>) -> ServerHandle {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let coord = Arc::clone(&self);
        let join = thread::Builder::new()
            .name("hmm-scan-server".into())
            .spawn(move || {
                let mut batcher: Batcher<Envelope> =
                    Batcher::new(coord.batcher_config);
                loop {
                    // Poll with a timeout bounded by the earliest batch
                    // deadline (backpressure: queue depth is bounded by
                    // the channel + batcher occupancy).
                    let timeout = batcher
                        .next_deadline()
                        .map(|d| d.saturating_duration_since(Instant::now()))
                        .unwrap_or(Duration::from_millis(50));
                    match rx.recv_timeout(timeout) {
                        Ok(ServerMsg::Request(req, reply)) => {
                            let key = match coord.plan_for(&req) {
                                Ok(plan) => plan_key(&plan),
                                Err(e) => {
                                    coord.metrics.on_failure();
                                    let _ = reply.send(Err(e));
                                    continue;
                                }
                            };
                            if let Some(batch) =
                                batcher.push(&key, Envelope { req, reply }, Instant::now())
                            {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                        }
                        Ok(ServerMsg::Shutdown) => {
                            for batch in batcher.flush_all() {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            for batch in batcher.flush_due(Instant::now()) {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
            })
            .expect("spawn server");
        ServerHandle { tx, join: Some(join) }
    }
}

/// Engine output → decode payload (training results are not servable).
fn decode_result_from(out: EngineOutput) -> Result<DecodeResult> {
    match out {
        EngineOutput::Posterior(p) => Ok(DecodeResult::Posterior(p)),
        EngineOutput::Map(m) => Ok(DecodeResult::Map(m)),
        EngineOutput::Training(_) => {
            Err(Error::coordinator("training output cannot be served"))
        }
    }
}

fn plan_key(plan: &ExecutionPlan) -> String {
    match plan {
        ExecutionPlan::PjrtCore { artifact, .. } => format!("pjrt:{artifact}"),
        ExecutionPlan::Sharded { fold_mid, .. } => format!("sharded:{fold_mid}"),
        ExecutionPlan::Native => "native".to_string(),
    }
}

struct Envelope {
    req: DecodeRequest,
    reply: mpsc::Sender<Result<DecodeResponse>>,
}

enum ServerMsg {
    Request(DecodeRequest, mpsc::Sender<Result<DecodeResponse>>),
    Shutdown,
}

/// Handle to a running serve loop.
pub struct ServerHandle {
    tx: mpsc::Sender<ServerMsg>,
    join: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: DecodeRequest) -> mpsc::Receiver<Result<DecodeResponse>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Request(req, reply));
        rx
    }

    /// Drain and stop the serve loop.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ExecMode;
    use crate::hmm::{gilbert_elliott, sample, GeParams};
    use crate::rng::Xoshiro256StarStar;

    fn native_coord() -> Coordinator {
        let c = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        c
    }

    #[test]
    fn native_decode_smoke() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(51);
        let tr = sample(&hmm, 200, &mut rng);
        let resp = c
            .decode(DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.plan, "native");
        let post = resp.result.as_posterior().unwrap();
        assert_eq!(post.len(), 200);
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        assert!((post.log_likelihood() - native.log_likelihood()).abs() < 1e-9);

        let resp = c
            .decode(DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Map))
            .unwrap();
        let est = resp.result.as_map().unwrap();
        assert_eq!(est.path.len(), 200);
    }

    #[test]
    fn unknown_model_and_bad_obs() {
        let c = native_coord();
        assert!(c.decode(DecodeRequest::new(1, "none", vec![0], Algo::Map)).is_err());
        assert!(c.decode(DecodeRequest::new(1, "ge", vec![9], Algo::Map)).is_err());
        assert!(c.decode(DecodeRequest::new(1, "ge", vec![], Algo::Map)).is_err());
        assert_eq!(c.metrics().snapshot().failed, 3);
    }

    #[test]
    fn native_decode_dispatches_through_engine() {
        // Repeated decodes reuse the per-model engine workspace and must
        // stay bit-identical — and match a standalone Engine exactly.
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(56);
        let tr = sample(&hmm, 300, &mut rng);
        let a = c
            .decode(DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        let b = c
            .decode(DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        assert_eq!(
            a.result.as_posterior().unwrap(),
            b.result.as_posterior().unwrap()
        );
        let mut engine = crate::engine::Engine::builder(hmm)
            .scan_options(ScanOptions::default())
            .build();
        let direct = engine
            .run(crate::engine::Algorithm::SpPar, &tr.observations)
            .unwrap()
            .into_posterior()
            .unwrap();
        assert_eq!(a.result.as_posterior().unwrap(), &direct);
    }

    #[test]
    fn decode_many_preserves_order() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(52);
        let reqs: Vec<DecodeRequest> = (0..10)
            .map(|i| {
                let tr = sample(&hmm, 50 + (i as usize % 3) * 10, &mut rng);
                DecodeRequest::new(i, "ge", tr.observations, Algo::Smooth)
            })
            .collect();
        let out = c.decode_many(reqs);
        assert_eq!(out.len(), 10);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().id, i as u64);
        }
    }

    // ---- PJRT-backed tests (skip when artifacts are absent) ----

    fn pjrt_coord() -> Option<Coordinator> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {dir:?}");
            return None;
        }
        let c = Coordinator::new(CoordinatorConfig {
            artifacts: Some(dir),
            xla_workers: 2,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        Some(c)
    }

    #[test]
    fn pjrt_core_decode_matches_native() {
        let Some(c) = pjrt_coord() else { return };
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(53);
        let tr = sample(&hmm, 100, &mut rng); // pads into T=128 artifact
        let req = DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth)
            .with_mode(ExecMode::Pjrt);
        let resp = c.decode(req).unwrap();
        assert!(resp.plan.starts_with("pjrt:sp_par_T128"), "{}", resp.plan);
        let post = resp.result.as_posterior().unwrap();
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        for k in 0..100 {
            for s in 0..4 {
                assert!((post.gamma(k)[s] - native.gamma(k)[s]).abs() < 1e-4);
            }
        }
        assert!(
            (post.log_likelihood() - native.log_likelihood()).abs()
                < 1e-3 * native.log_likelihood().abs()
        );
    }

    #[test]
    fn sharded_decode_matches_native() {
        let Some(c) = pjrt_coord() else { return };
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(54);
        // Longer than the largest (8192) core artifact → sharded.
        let tr = sample(&hmm, 10_000, &mut rng);
        let req = DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth);
        let plan = c.plan_for(&req).unwrap();
        assert!(matches!(plan, ExecutionPlan::Sharded { .. }), "{plan:?}");
        let resp = c.decode(req).unwrap();
        let post = resp.result.as_posterior().unwrap();
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        let mut max_err = 0.0f64;
        for k in 0..10_000 {
            for s in 0..4 {
                max_err = max_err.max((post.gamma(k)[s] - native.gamma(k)[s]).abs());
            }
        }
        assert!(max_err < 1e-3, "sharded smoother max err {max_err}");
        assert!(c.metrics().snapshot().sharded_blocks > 0);

        // MAP, sharded.
        let req = DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Map);
        let resp = c.decode(req).unwrap();
        let est = resp.result.as_map().unwrap();
        let native = crate::inference::viterbi(&hmm, &tr.observations).unwrap();
        assert!(
            (est.log_prob - native.log_prob).abs()
                < 1e-3 * native.log_prob.abs(),
            "{} vs {}",
            est.log_prob,
            native.log_prob
        );
    }

    #[test]
    fn serve_loop_round_trip() {
        let c = Arc::new(native_coord());
        let handle = Arc::clone(&c).serve();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(55);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                let tr = sample(&hmm, 64, &mut rng);
                handle.submit(DecodeRequest::new(i, "ge", tr.observations, Algo::Smooth))
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, i as u64);
        }
        handle.shutdown();
        let snap = c.metrics().snapshot();
        assert_eq!(snap.completed, 8);
        assert!(snap.batches >= 1);
    }
}

//! The coordinator server: XLA worker pool, model registry, decode entry
//! points, and the channel-fed serve loop.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::engine::{Engine, EngineOutput, Session, XlaBackend};
use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::runtime::{ArtifactExec, Manifest, Registry, Value};
use crate::scan::ScanOptions;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{
    Algo, DecodeRequest, DecodeResponse, DecodeResult, StreamReply,
    StreamRequest, StreamResponse, StreamVerb,
};
use super::router::{ExecutionPlan, Router, RouterConfig};
use super::sharder::{self, ShardedArtifacts};

// ===========================================================================
// XLA worker pool
// ===========================================================================

struct Job {
    artifact: String,
    inputs: Vec<Value>,
    reply: mpsc::Sender<Result<Vec<Value>>>,
}

/// Pool of threads each owning a private PJRT client + executable cache
/// (`xla::PjRtClient` is `Rc`-based and cannot cross threads, so worker
/// isolation is per-thread by construction). Jobs are distributed over a
/// shared queue; per-worker caches converge to the hot artifact set.
pub struct XlaPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl XlaPool {
    pub fn new(dir: PathBuf, workers: usize) -> Result<Self> {
        // Validate the manifest once up front for a fast, typed failure.
        Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let dir = dir.clone();
                thread::Builder::new()
                    .name(format!("xla-worker-{i}"))
                    .spawn(move || {
                        let registry = Registry::open(dir);
                        loop {
                            let job = {
                                let guard = rx.lock().expect("xla queue poisoned");
                                guard.recv()
                            };
                            let Ok(job) = job else { break };
                            let result = match &registry {
                                Ok(reg) => reg
                                    .get(&job.artifact)
                                    .and_then(|exe| exe.run(&job.inputs)),
                                Err(e) => Err(Error::xla(format!(
                                    "worker init failed: {e}"
                                ))),
                            };
                            let _ = job.reply.send(result);
                        }
                    })
                    .expect("spawn xla worker")
            })
            .collect();
        Ok(Self { tx: Some(tx), workers })
    }

    /// Submit a job; returns the reply channel.
    pub fn submit(
        &self,
        artifact: &str,
        inputs: Vec<Value>,
    ) -> mpsc::Receiver<Result<Vec<Value>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Job { artifact: artifact.to_string(), inputs, reply })
            .expect("xla queue closed");
        rx
    }
}

impl Drop for XlaPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl ArtifactExec for XlaPool {
    fn run(&self, artifact: &str, inputs: Vec<Value>) -> Result<Vec<Value>> {
        self.submit(artifact, inputs)
            .recv()
            .map_err(|_| Error::coordinator("xla worker dropped reply"))?
    }

    fn run_many(&self, jobs: Vec<(String, Vec<Value>)>) -> Vec<Result<Vec<Value>>> {
        // Dispatch everything, then collect — folds/finalizes of a
        // sharded plan run genuinely concurrently across workers.
        let rxs: Vec<_> = jobs
            .into_iter()
            .map(|(a, i)| self.submit(&a, i))
            .collect();
        rxs.into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| Error::coordinator("xla worker dropped reply"))?
            })
            .collect()
    }
}

// ===========================================================================
// Coordinator
// ===========================================================================

/// Coordinator construction parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifacts directory; `None` disables PJRT (native-only serving).
    pub artifacts: Option<PathBuf>,
    /// XLA worker threads (each owns a PJRT client).
    pub xla_workers: usize,
    pub batcher: BatcherConfig,
    pub router: RouterConfig,
    /// Threading for the native algorithm library.
    pub scan: ScanOptions,
    /// Upper bound on the fixed-lag width a streaming client may request
    /// at open. Every append runs an O(lag + block) window query on the
    /// serve loop, so an unbounded client-supplied lag would let one
    /// session degrade all traffic to O(T) per append.
    pub max_stream_lag: usize,
    /// Upper bound on concurrently open streaming sessions. Each session
    /// retains its O(T) element chain, so an unchecked open loop (or
    /// clients that never close) would exhaust coordinator memory;
    /// opens beyond the cap are rejected with a typed error. (Idle
    /// eviction to disk is a ROADMAP follow-on.)
    pub max_open_sessions: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifacts: {
                let dir = crate::runtime::artifacts_dir();
                dir.join("manifest.json").exists().then_some(dir)
            },
            xla_workers: 4,
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            scan: ScanOptions::default(),
            max_stream_lag: 4096,
            max_open_sessions: 1024,
        }
    }
}

impl CoordinatorConfig {
    /// Native-only configuration (no artifacts required).
    pub fn native_only() -> Self {
        Self { artifacts: None, ..Default::default() }
    }
}

/// The inference service.
///
/// All native execution dispatches through one [`Engine`] per registered
/// model (serialized by a per-model mutex so the engine's scratch
/// workspace is reused across requests); the PJRT core-artifact path
/// dispatches through the engine's [`XlaBackend`].
pub struct Coordinator {
    manifest: Option<Arc<Manifest>>,
    pool: Option<Arc<XlaPool>>,
    xla: Option<XlaBackend>,
    router: Router,
    models: RwLock<BTreeMap<String, ModelEntry>>,
    /// Streaming sessions, keyed like the per-model engine map: each
    /// entry owns its mutex-serialized `engine::Session` (the session's
    /// workspace is reused across appends exactly as the per-model
    /// engine's is across decodes).
    sessions: RwLock<BTreeMap<u64, Arc<SessionEntry>>>,
    next_session: AtomicU64,
    max_stream_lag: usize,
    max_open_sessions: usize,
    metrics: Arc<Metrics>,
    scan: ScanOptions,
    batcher_config: BatcherConfig,
}

/// One registered model: the Hmm and its serving engine, paired in a
/// single map entry so a concurrent re-registration can never match a
/// new model with a stale engine (or vice versa).
#[derive(Clone)]
struct ModelEntry {
    hmm: Arc<Hmm>,
    engine: Arc<Mutex<Engine>>,
}

/// One open streaming session: the session state plus the model handle
/// (for the router's window hints) and the fixed-lag width appends
/// report at.
struct SessionEntry {
    session: Mutex<Session>,
    hmm: Arc<Hmm>,
    lag: usize,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Result<Self> {
        let (manifest, pool) = match &config.artifacts {
            Some(dir) => {
                let manifest = Arc::new(Manifest::load(dir)?);
                let pool = Arc::new(XlaPool::new(dir.clone(), config.xla_workers)?);
                (Some(manifest), Some(pool))
            }
            None => (None, None),
        };
        let xla = match (&manifest, &pool) {
            (Some(m), Some(p)) => {
                let exec: Arc<dyn ArtifactExec + Send + Sync> = Arc::clone(p);
                Some(XlaBackend::new(exec, Arc::clone(m)))
            }
            _ => None,
        };
        Ok(Self {
            manifest,
            pool,
            xla,
            router: Router::new(config.router),
            models: RwLock::new(BTreeMap::new()),
            sessions: RwLock::new(BTreeMap::new()),
            next_session: AtomicU64::new(0),
            max_stream_lag: config.max_stream_lag,
            max_open_sessions: config.max_open_sessions,
            metrics: Arc::new(Metrics::new()),
            scan: config.scan,
            batcher_config: config.batcher,
        })
    }

    pub fn register_model(&self, id: impl Into<String>, hmm: Hmm) {
        let hmm = Arc::new(hmm);
        let engine = Engine::builder(Arc::clone(&hmm))
            .scan_options(self.scan)
            .build();
        let entry = ModelEntry { hmm, engine: Arc::new(Mutex::new(engine)) };
        self.models.write().unwrap().insert(id.into(), entry);
    }

    fn entry(&self, id: &str) -> Result<ModelEntry> {
        self.models
            .read()
            .unwrap()
            .get(id)
            .cloned()
            .ok_or_else(|| Error::invalid_request(format!("unknown model '{id}'")))
    }

    pub fn model(&self, id: &str) -> Result<Arc<Hmm>> {
        Ok(self.entry(id)?.hmm)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Resolve the plan a request would execute (exposed for tests/CLI).
    pub fn plan_for(&self, req: &DecodeRequest) -> Result<ExecutionPlan> {
        let hmm = self.model(&req.model)?;
        hmm.check_observations(&req.ys)?;
        self.router.plan(
            self.manifest.as_deref(),
            req,
            hmm.num_states(),
            hmm.num_symbols(),
        )
    }

    /// Serve one request synchronously.
    pub fn decode(&self, req: DecodeRequest) -> Result<DecodeResponse> {
        self.metrics.on_request();
        let start = Instant::now();
        let result = self.execute(&req);
        match result {
            Ok((result, plan)) => {
                let elapsed = start.elapsed();
                self.metrics.on_complete(elapsed);
                Ok(DecodeResponse { id: req.id, result, plan, elapsed })
            }
            Err(e) => {
                self.metrics.on_failure();
                Err(e)
            }
        }
    }

    /// Serve a group of requests through the batcher: requests that
    /// resolve to the same artifact are dispatched back-to-back so the
    /// XLA pool executes them concurrently.
    pub fn decode_many(
        &self,
        reqs: Vec<DecodeRequest>,
    ) -> Vec<Result<DecodeResponse>> {
        let mut batcher: Batcher<(usize, DecodeRequest)> =
            Batcher::new(self.batcher_config);
        let now = Instant::now();
        let mut batches = Vec::new();
        for (idx, req) in reqs.into_iter().enumerate() {
            let key = match self.plan_for(&req) {
                Ok(plan) => plan_key(&plan),
                Err(_) => "invalid".to_string(), // decode() reports the error
            };
            if let Some(b) = batcher.push(&key, (idx, req), now) {
                batches.push(b);
            }
        }
        batches.extend(batcher.flush_all());

        let mut out: Vec<Option<Result<DecodeResponse>>> = Vec::new();
        for batch in &batches {
            self.metrics.on_batch(batch.items.len());
            out.resize_with(
                out.len().max(batch.items.iter().map(|(i, _)| i + 1).max().unwrap_or(0)),
                || None,
            );
        }
        for batch in batches {
            for (idx, req) in batch.items {
                let resp = self.decode(req);
                if idx >= out.len() {
                    out.resize_with(idx + 1, || None);
                }
                out[idx] = Some(resp);
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(Error::coordinator("lost request"))))
            .collect()
    }

    /// Serve one streaming verb synchronously (open / append / close —
    /// see [`StreamVerb`]). Appends return the filtering marginal, and a
    /// fixed-lag smoothing window when the session was opened with
    /// `lag` > 0; close returns the exact full-sequence posterior
    /// (bit-identical to the one-shot parallel smoother under the
    /// session's scan options) and removes the session.
    pub fn stream(&self, req: StreamRequest) -> Result<StreamResponse> {
        let start = Instant::now();
        match self.stream_verb(req.verb, start) {
            Ok(reply) => {
                Ok(StreamResponse { id: req.id, reply, elapsed: start.elapsed() })
            }
            Err(e) => {
                self.metrics.on_failure();
                Err(e)
            }
        }
    }

    fn stream_verb(&self, verb: StreamVerb, start: Instant) -> Result<StreamReply> {
        match verb {
            StreamVerb::Open { model, options, lag } => {
                if lag > self.max_stream_lag {
                    return Err(Error::invalid_request(format!(
                        "requested lag {lag} exceeds the configured maximum {}",
                        self.max_stream_lag
                    )));
                }
                // The append cost is O(lag + block), so the block is
                // capped alongside the lag — otherwise a huge client
                // block re-opens the degrade-every-append hole the lag
                // cap closes.
                let max_block =
                    self.max_stream_lag.max(crate::engine::DEFAULT_SESSION_BLOCK);
                if options.block.is_some_and(|b| b > max_block) {
                    return Err(Error::invalid_request(format!(
                        "requested block {} exceeds the maximum {max_block}",
                        options.block.unwrap_or(0)
                    )));
                }
                let entry = self.entry(&model)?;
                let session = {
                    let engine =
                        entry.engine.lock().expect("engine mutex poisoned");
                    engine.open_session(options)
                };
                let id = self.next_session.fetch_add(1, Ordering::Relaxed) + 1;
                {
                    let mut sessions = self.sessions.write().unwrap();
                    if sessions.len() >= self.max_open_sessions {
                        return Err(Error::invalid_request(format!(
                            "open session limit {} reached",
                            self.max_open_sessions
                        )));
                    }
                    sessions.insert(
                        id,
                        Arc::new(SessionEntry {
                            session: Mutex::new(session),
                            hmm: entry.hmm,
                            lag,
                        }),
                    );
                }
                self.metrics.on_session_open();
                Ok(StreamReply::Opened { session: id })
            }
            StreamVerb::Append { session, ys } => {
                let entry = self.session_entry(session)?;
                let mut s = entry.session.lock().expect("session mutex poisoned");
                s.push(&ys)?;
                let filtered = s.filtered()?;
                let (window, plan_hint) = if entry.lag > 0 {
                    let win = s.smoothed_lag(entry.lag)?;
                    self.metrics.on_suffix_width(win.rescan_width);
                    let hint = self.router.window_hint(
                        self.manifest.as_deref(),
                        Algo::Smooth,
                        win.rescan_width,
                        entry.hmm.num_states(),
                        entry.hmm.num_symbols(),
                    );
                    (Some(win), hint)
                } else {
                    (None, None)
                };
                let len = s.len();
                drop(s);
                self.metrics.on_append(ys.len(), start.elapsed());
                Ok(StreamReply::Appended { session, len, filtered, window, plan_hint })
            }
            StreamVerb::Close { session } => {
                let entry = self.session_entry(session)?;
                let mut s = entry.session.lock().expect("session mutex poisoned");
                // finish() before removal: closing a session with no
                // observations is an error that leaves it open (the
                // client can append and retry), never a silent drop.
                let posterior = s.finish()?;
                drop(s);
                if self.sessions.write().unwrap().remove(&session).is_some() {
                    self.metrics.on_session_close();
                }
                Ok(StreamReply::Closed { session, posterior })
            }
        }
    }

    fn session_entry(&self, id: u64) -> Result<Arc<SessionEntry>> {
        self.sessions
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::invalid_request(format!("unknown session {id}")))
    }

    /// Number of currently open streaming sessions.
    pub fn open_sessions(&self) -> usize {
        self.sessions.read().unwrap().len()
    }

    fn execute(&self, req: &DecodeRequest) -> Result<(DecodeResult, String)> {
        // Fetch the model/engine pair once, atomically, so a concurrent
        // re-registration cannot switch models between plan and run.
        let entry = self.entry(&req.model)?;
        let hmm = entry.hmm;
        hmm.check_observations(&req.ys)?;
        let plan = self.router.plan(
            self.manifest.as_deref(),
            req,
            hmm.num_states(),
            hmm.num_symbols(),
        )?;
        let tag = plan.describe(req.ys.len());
        let result = match &plan {
            ExecutionPlan::Native => {
                let mut engine = entry.engine.lock().expect("engine mutex poisoned");
                decode_result_from(engine.run(req.algo.parallel(), &req.ys)?)?
            }
            ExecutionPlan::PjrtCore { artifact, capacity } => {
                self.run_pjrt_core(&hmm, req, artifact, *capacity)?
            }
            ExecutionPlan::Sharded {
                fold_first,
                fold_mid,
                finalize_first,
                finalize_mid,
                block_len,
                num_blocks,
            } => {
                self.metrics.on_sharded_blocks(*num_blocks);
                let arts = ShardedArtifacts {
                    fold_first: fold_first.clone(),
                    fold_mid: fold_mid.clone(),
                    finalize_first: finalize_first.clone(),
                    finalize_mid: finalize_mid.clone(),
                    block_len: *block_len,
                };
                let pool = self
                    .pool
                    .as_ref()
                    .ok_or_else(|| Error::coordinator("no xla pool"))?;
                match req.algo {
                    Algo::Map => {
                        let (est, _) =
                            sharder::mp_sharded(&**pool, &arts, &hmm, &req.ys)?;
                        DecodeResult::Map(est)
                    }
                    Algo::Smooth | Algo::BayesSmooth => {
                        let (post, _) =
                            sharder::sp_sharded(&**pool, &arts, &hmm, &req.ys)?;
                        DecodeResult::Posterior(post)
                    }
                }
            }
        };
        Ok((result, tag))
    }

    /// PJRT-core plan: dispatch through the engine's XLA backend, which
    /// owns the marshal/decode contract with the compiled artifacts.
    fn run_pjrt_core(
        &self,
        hmm: &Hmm,
        req: &DecodeRequest,
        artifact: &str,
        capacity: usize,
    ) -> Result<DecodeResult> {
        let xla = self
            .xla
            .as_ref()
            .ok_or_else(|| Error::coordinator("no xla backend"))?;
        decode_result_from(xla.run_artifact(
            hmm,
            req.algo.parallel(),
            &req.ys,
            artifact,
            capacity,
        )?)
    }

    /// Spawn the serve loop on its own thread; returns a submit handle.
    pub fn serve(self: Arc<Self>) -> ServerHandle {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let coord = Arc::clone(&self);
        let join = thread::Builder::new()
            .name("hmm-scan-server".into())
            .spawn(move || {
                let mut batcher: Batcher<Envelope> =
                    Batcher::new(coord.batcher_config);
                loop {
                    // Poll with a timeout bounded by the earliest batch
                    // deadline (backpressure: queue depth is bounded by
                    // the channel + batcher occupancy).
                    let timeout = batcher
                        .next_deadline()
                        .map(|d| d.saturating_duration_since(Instant::now()))
                        .unwrap_or(Duration::from_millis(50));
                    match rx.recv_timeout(timeout) {
                        Ok(ServerMsg::Request(req, reply)) => {
                            let key = match coord.plan_for(&req) {
                                Ok(plan) => plan_key(&plan),
                                Err(e) => {
                                    coord.metrics.on_failure();
                                    let _ = reply.send(Err(e));
                                    continue;
                                }
                            };
                            if let Some(batch) =
                                batcher.push(&key, Envelope { req, reply }, Instant::now())
                            {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                        }
                        Ok(ServerMsg::Stream(req, reply)) => {
                            // Streaming verbs bypass the batcher: an
                            // append is latency-sensitive and already
                            // O(k) — coalescing buys nothing.
                            let _ = reply.send(coord.stream(req));
                        }
                        Ok(ServerMsg::Shutdown) => {
                            for batch in batcher.flush_all() {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            for batch in batcher.flush_due(Instant::now()) {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
            })
            .expect("spawn server");
        ServerHandle { tx, join: Some(join) }
    }
}

/// Engine output → decode payload (training results are not servable).
fn decode_result_from(out: EngineOutput) -> Result<DecodeResult> {
    match out {
        EngineOutput::Posterior(p) => Ok(DecodeResult::Posterior(p)),
        EngineOutput::Map(m) => Ok(DecodeResult::Map(m)),
        EngineOutput::Training(_) => {
            Err(Error::coordinator("training output cannot be served"))
        }
    }
}

fn plan_key(plan: &ExecutionPlan) -> String {
    match plan {
        ExecutionPlan::PjrtCore { artifact, .. } => format!("pjrt:{artifact}"),
        ExecutionPlan::Sharded { fold_mid, .. } => format!("sharded:{fold_mid}"),
        ExecutionPlan::Native => "native".to_string(),
    }
}

struct Envelope {
    req: DecodeRequest,
    reply: mpsc::Sender<Result<DecodeResponse>>,
}

enum ServerMsg {
    Request(DecodeRequest, mpsc::Sender<Result<DecodeResponse>>),
    Stream(StreamRequest, mpsc::Sender<Result<StreamResponse>>),
    Shutdown,
}

/// Handle to a running serve loop.
pub struct ServerHandle {
    tx: mpsc::Sender<ServerMsg>,
    join: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: DecodeRequest) -> mpsc::Receiver<Result<DecodeResponse>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Request(req, reply));
        rx
    }

    /// Submit a streaming verb (open / append / close); served ahead of
    /// any batching deadline.
    pub fn submit_stream(
        &self,
        req: StreamRequest,
    ) -> mpsc::Receiver<Result<StreamResponse>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Stream(req, reply));
        rx
    }

    /// Drain and stop the serve loop.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ExecMode;
    use crate::hmm::{gilbert_elliott, sample, GeParams};
    use crate::rng::Xoshiro256StarStar;

    fn native_coord() -> Coordinator {
        let c = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        c
    }

    #[test]
    fn native_decode_smoke() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(51);
        let tr = sample(&hmm, 200, &mut rng);
        let resp = c
            .decode(DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.plan, "native");
        let post = resp.result.as_posterior().unwrap();
        assert_eq!(post.len(), 200);
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        assert!((post.log_likelihood() - native.log_likelihood()).abs() < 1e-9);

        let resp = c
            .decode(DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Map))
            .unwrap();
        let est = resp.result.as_map().unwrap();
        assert_eq!(est.path.len(), 200);
    }

    #[test]
    fn unknown_model_and_bad_obs() {
        let c = native_coord();
        assert!(c.decode(DecodeRequest::new(1, "none", vec![0], Algo::Map)).is_err());
        assert!(c.decode(DecodeRequest::new(1, "ge", vec![9], Algo::Map)).is_err());
        assert!(c.decode(DecodeRequest::new(1, "ge", vec![], Algo::Map)).is_err());
        assert_eq!(c.metrics().snapshot().failed, 3);
    }

    #[test]
    fn native_decode_dispatches_through_engine() {
        // Repeated decodes reuse the per-model engine workspace and must
        // stay bit-identical — and match a standalone Engine exactly.
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(56);
        let tr = sample(&hmm, 300, &mut rng);
        let a = c
            .decode(DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        let b = c
            .decode(DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        assert_eq!(
            a.result.as_posterior().unwrap(),
            b.result.as_posterior().unwrap()
        );
        let mut engine = crate::engine::Engine::builder(hmm)
            .scan_options(ScanOptions::default())
            .build();
        let direct = engine
            .run(crate::engine::Algorithm::SpPar, &tr.observations)
            .unwrap()
            .into_posterior()
            .unwrap();
        assert_eq!(a.result.as_posterior().unwrap(), &direct);
    }

    #[test]
    fn decode_many_preserves_order() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(52);
        let reqs: Vec<DecodeRequest> = (0..10)
            .map(|i| {
                let tr = sample(&hmm, 50 + (i as usize % 3) * 10, &mut rng);
                DecodeRequest::new(i, "ge", tr.observations, Algo::Smooth)
            })
            .collect();
        let out = c.decode_many(reqs);
        assert_eq!(out.len(), 10);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().id, i as u64);
        }
    }

    #[test]
    fn streaming_open_append_close_round_trip() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(57);
        let tr = sample(&hmm, 300, &mut rng);
        let ys = &tr.observations;

        let resp = c.stream(StreamRequest::open(1, "ge", 16)).unwrap();
        let StreamReply::Opened { session } = resp.reply else {
            panic!("expected Opened, got {:?}", resp.reply)
        };
        assert_eq!(c.open_sessions(), 1);

        let mut pushed = 0usize;
        for (i, chunk) in ys.chunks(100).enumerate() {
            let resp = c
                .stream(StreamRequest::append(10 + i as u64, session, chunk.to_vec()))
                .unwrap();
            pushed += chunk.len();
            let StreamReply::Appended { len, filtered, window, .. } = resp.reply
            else {
                panic!("expected Appended")
            };
            assert_eq!(len, pushed);
            assert_eq!(filtered.step, pushed);
            assert_eq!(filtered.probs.len(), 4);
            let win = window.expect("lag > 0 returns a window");
            assert_eq!(win.posterior.len(), 16.min(pushed));
            // Window loglik is the running full-prefix likelihood.
            let want = crate::inference::sp_seq(&hmm, &ys[..pushed]).unwrap();
            assert!(
                (win.posterior.log_likelihood() - want.log_likelihood()).abs()
                    < 1e-9 * (1.0 + want.log_likelihood().abs())
            );
        }

        let resp = c.stream(StreamRequest::close(99, session)).unwrap();
        let StreamReply::Closed { posterior, .. } = resp.reply else {
            panic!("expected Closed")
        };
        assert_eq!(c.open_sessions(), 0);
        assert_eq!(posterior.len(), 300);
        let want = crate::inference::sp_seq(&hmm, ys).unwrap();
        assert!(
            (posterior.log_likelihood() - want.log_likelihood()).abs()
                < 1e-9 * (1.0 + want.log_likelihood().abs())
        );
        for k in 0..300 {
            for s in 0..4 {
                assert!((posterior.gamma(k)[s] - want.gamma(k)[s]).abs() < 1e-9);
            }
        }

        let snap = c.metrics().snapshot();
        assert_eq!(snap.sessions_opened, 1);
        assert_eq!(snap.sessions_closed, 1);
        assert_eq!(snap.appends, 3);
        assert_eq!(snap.appended_obs, 300);
        assert!(!snap.suffix_width_hist.is_empty());

        // The closed session is gone; unknown ids and bad verbs fail.
        assert!(c.stream(StreamRequest::append(1, session, vec![0])).is_err());
        assert!(c.stream(StreamRequest::close(1, session)).is_err());
        assert!(c.stream(StreamRequest::open(1, "nope", 0)).is_err());
        let resp = c.stream(StreamRequest::open(2, "ge", 0)).unwrap();
        let StreamReply::Opened { session } = resp.reply else { panic!() };
        // Out-of-range symbol: the append fails, the session survives.
        assert!(c.stream(StreamRequest::append(3, session, vec![9])).is_err());
        let resp = c.stream(StreamRequest::append(4, session, vec![0, 1])).unwrap();
        let StreamReply::Appended { window, .. } = resp.reply else { panic!() };
        assert!(window.is_none(), "lag = 0 sessions are filtering-only");

        // A lag beyond the configured cap is rejected at open, and so is
        // an oversized client-chosen checkpoint block (same O(lag + B)
        // append-cost guarantee).
        assert!(c.stream(StreamRequest::open(5, "ge", 1_000_000)).is_err());
        let big_block = StreamRequest {
            id: 5,
            verb: StreamVerb::Open {
                model: "ge".into(),
                options: crate::engine::SessionOptions {
                    block: Some(1 << 30),
                    ..Default::default()
                },
                lag: 8,
            },
        };
        assert!(c.stream(big_block).is_err());

        // Closing a session with no observations errors but leaves it
        // open — the client can append and retry.
        let resp = c.stream(StreamRequest::open(6, "ge", 0)).unwrap();
        let StreamReply::Opened { session: empty } = resp.reply else { panic!() };
        let before = c.open_sessions();
        assert!(c.stream(StreamRequest::close(7, empty)).is_err());
        assert_eq!(c.open_sessions(), before, "failed close must not drop");
        c.stream(StreamRequest::append(8, empty, vec![1, 0])).unwrap();
        assert!(c.stream(StreamRequest::close(9, empty)).is_ok());
        assert_eq!(c.open_sessions(), before - 1);
    }

    #[test]
    fn open_session_limit_is_enforced() {
        let c = Coordinator::new(CoordinatorConfig {
            max_open_sessions: 2,
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        let a = c.stream(StreamRequest::open(1, "ge", 0)).unwrap();
        c.stream(StreamRequest::open(2, "ge", 0)).unwrap();
        assert!(c.stream(StreamRequest::open(3, "ge", 0)).is_err());
        // Closing one frees a slot.
        let StreamReply::Opened { session } = a.reply else { panic!() };
        c.stream(StreamRequest::append(4, session, vec![0, 1])).unwrap();
        c.stream(StreamRequest::close(5, session)).unwrap();
        assert!(c.stream(StreamRequest::open(6, "ge", 0)).is_ok());
    }

    #[test]
    fn serve_loop_streams_alongside_decodes() {
        let c = Arc::new(native_coord());
        let handle = Arc::clone(&c).serve();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(58);

        let opened = handle
            .submit_stream(StreamRequest::open(0, "ge", 8))
            .recv()
            .unwrap()
            .unwrap();
        let StreamReply::Opened { session } = opened.reply else { panic!() };

        // Interleave decodes and appends through the same loop.
        let tr = sample(&hmm, 64, &mut rng);
        let decode_rx =
            handle.submit(DecodeRequest::new(7, "ge", tr.observations, Algo::Smooth));
        let append_rx = handle.submit_stream(StreamRequest::append(
            1,
            session,
            sample(&hmm, 50, &mut rng).observations,
        ));
        assert!(append_rx.recv().unwrap().is_ok());
        assert!(decode_rx.recv().unwrap().is_ok());

        let closed = handle
            .submit_stream(StreamRequest::close(2, session))
            .recv()
            .unwrap()
            .unwrap();
        match closed.reply {
            StreamReply::Closed { posterior, .. } => assert_eq!(posterior.len(), 50),
            other => panic!("expected Closed, got {other:?}"),
        }
        handle.shutdown();
        let snap = c.metrics().snapshot();
        assert_eq!(snap.sessions_opened, 1);
        assert_eq!(snap.sessions_closed, 1);
        assert_eq!(snap.completed, 1);
    }

    // ---- PJRT-backed tests (skip when artifacts are absent) ----

    fn pjrt_coord() -> Option<Coordinator> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {dir:?}");
            return None;
        }
        let c = Coordinator::new(CoordinatorConfig {
            artifacts: Some(dir),
            xla_workers: 2,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        Some(c)
    }

    #[test]
    fn pjrt_core_decode_matches_native() {
        let Some(c) = pjrt_coord() else { return };
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(53);
        let tr = sample(&hmm, 100, &mut rng); // pads into T=128 artifact
        let req = DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth)
            .with_mode(ExecMode::Pjrt);
        let resp = c.decode(req).unwrap();
        assert!(resp.plan.starts_with("pjrt:sp_par_T128"), "{}", resp.plan);
        let post = resp.result.as_posterior().unwrap();
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        for k in 0..100 {
            for s in 0..4 {
                assert!((post.gamma(k)[s] - native.gamma(k)[s]).abs() < 1e-4);
            }
        }
        assert!(
            (post.log_likelihood() - native.log_likelihood()).abs()
                < 1e-3 * native.log_likelihood().abs()
        );
    }

    #[test]
    fn sharded_decode_matches_native() {
        let Some(c) = pjrt_coord() else { return };
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(54);
        // Longer than the largest (8192) core artifact → sharded.
        let tr = sample(&hmm, 10_000, &mut rng);
        let req = DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth);
        let plan = c.plan_for(&req).unwrap();
        assert!(matches!(plan, ExecutionPlan::Sharded { .. }), "{plan:?}");
        let resp = c.decode(req).unwrap();
        let post = resp.result.as_posterior().unwrap();
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        let mut max_err = 0.0f64;
        for k in 0..10_000 {
            for s in 0..4 {
                max_err = max_err.max((post.gamma(k)[s] - native.gamma(k)[s]).abs());
            }
        }
        assert!(max_err < 1e-3, "sharded smoother max err {max_err}");
        assert!(c.metrics().snapshot().sharded_blocks > 0);

        // MAP, sharded.
        let req = DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Map);
        let resp = c.decode(req).unwrap();
        let est = resp.result.as_map().unwrap();
        let native = crate::inference::viterbi(&hmm, &tr.observations).unwrap();
        assert!(
            (est.log_prob - native.log_prob).abs()
                < 1e-3 * native.log_prob.abs(),
            "{} vs {}",
            est.log_prob,
            native.log_prob
        );
    }

    #[test]
    fn serve_loop_round_trip() {
        let c = Arc::new(native_coord());
        let handle = Arc::clone(&c).serve();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(55);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                let tr = sample(&hmm, 64, &mut rng);
                handle.submit(DecodeRequest::new(i, "ge", tr.observations, Algo::Smooth))
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, i as u64);
        }
        handle.shutdown();
        let snap = c.metrics().snapshot();
        assert_eq!(snap.completed, 8);
        assert!(snap.batches >= 1);
    }
}

//! The coordinator server: XLA worker pool, model registry, decode entry
//! points, the durable session registry (watermark-driven eviction to a
//! `store::SessionStore`, transparent restore, crash recovery), the
//! background housekeeping worker that keeps spills and log compactions
//! off the serve path, and the channel-fed serve loop.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::engine::{
    Engine, EngineOutput, Filtered, Session, SessionKind, XlaBackend,
};
use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::kalman::{KalmanEngine, Lgssm};
use crate::obs::span::StageSpan;
use crate::obs::{Timeline, TimelineEvent};
use crate::runtime::{ArtifactExec, Manifest, Registry, Value};
use crate::scan::ScanOptions;
use crate::store::{
    lgssm_fingerprint, model_fingerprint, DiskStore, MemStore, SessionMeta,
    SessionStore, DEFAULT_GROUP_COMMIT_WINDOW,
};

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{
    Algo, DecodeRequest, DecodeResponse, DecodeResult, StreamReply,
    StreamRequest, StreamResponse, StreamVerb,
};
use super::router::{ExecutionPlan, Router, RouterConfig};
use super::sharder::{self, ShardedArtifacts};

// ===========================================================================
// XLA worker pool
// ===========================================================================

struct Job {
    artifact: String,
    inputs: Vec<Value>,
    reply: mpsc::Sender<Result<Vec<Value>>>,
}

/// Pool of threads each owning a private PJRT client + executable cache
/// (`xla::PjRtClient` is `Rc`-based and cannot cross threads, so worker
/// isolation is per-thread by construction). Jobs are distributed over a
/// shared queue; per-worker caches converge to the hot artifact set.
pub struct XlaPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl XlaPool {
    /// Spawn `workers` PJRT worker threads over the artifact directory
    /// `dir` (validating its manifest up front).
    pub fn new(dir: PathBuf, workers: usize) -> Result<Self> {
        // Validate the manifest once up front for a fast, typed failure.
        Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let dir = dir.clone();
                thread::Builder::new()
                    .name(format!("xla-worker-{i}"))
                    .spawn(move || {
                        let registry = Registry::open(dir);
                        loop {
                            let job = {
                                let guard = rx.lock().expect("xla queue poisoned");
                                guard.recv()
                            };
                            let Ok(job) = job else { break };
                            let result = match &registry {
                                Ok(reg) => reg
                                    .get(&job.artifact)
                                    .and_then(|exe| exe.run(&job.inputs)),
                                Err(e) => Err(Error::xla(format!(
                                    "worker init failed: {e}"
                                ))),
                            };
                            let _ = job.reply.send(result);
                        }
                    })
                    .expect("spawn xla worker")
            })
            .collect();
        Ok(Self { tx: Some(tx), workers })
    }

    /// Submit a job; returns the reply channel.
    pub fn submit(
        &self,
        artifact: &str,
        inputs: Vec<Value>,
    ) -> mpsc::Receiver<Result<Vec<Value>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Job { artifact: artifact.to_string(), inputs, reply })
            .expect("xla queue closed");
        rx
    }
}

impl Drop for XlaPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl ArtifactExec for XlaPool {
    fn run(&self, artifact: &str, inputs: Vec<Value>) -> Result<Vec<Value>> {
        self.submit(artifact, inputs)
            .recv()
            .map_err(|_| Error::coordinator("xla worker dropped reply"))?
    }

    fn run_many(&self, jobs: Vec<(String, Vec<Value>)>) -> Vec<Result<Vec<Value>>> {
        // Dispatch everything, then collect — folds/finalizes of a
        // sharded plan run genuinely concurrently across workers.
        let rxs: Vec<_> = jobs
            .into_iter()
            .map(|(a, i)| self.submit(&a, i))
            .collect();
        rxs.into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| Error::coordinator("xla worker dropped reply"))?
            })
            .collect()
    }
}

// ===========================================================================
// Coordinator
// ===========================================================================

/// Coordinator construction parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifacts directory; `None` disables PJRT (native-only serving).
    pub artifacts: Option<PathBuf>,
    /// XLA worker threads (each owns a PJRT client).
    pub xla_workers: usize,
    /// Decode-batching policy (window + max batch size).
    pub batcher: BatcherConfig,
    /// Plan-selection policy (artifact routing thresholds).
    pub router: RouterConfig,
    /// Threading for the native algorithm library.
    pub scan: ScanOptions,
    /// Upper bound on the fixed-lag width a streaming client may request
    /// at open. Every append runs an O(lag + block) window query on the
    /// serve loop, so an unbounded client-supplied lag would let one
    /// session degrade all traffic to O(T) per append.
    pub max_stream_lag: usize,
    /// Resident-RAM watermark: the number of streaming sessions allowed
    /// to keep their O(T·D²) element chains in memory. This is *not* an
    /// open cap — opens beyond it succeed; the least-recently-appended
    /// sessions are spilled to the session store and restored
    /// transparently (bit-identically) on their next touch. Note the
    /// bound this buys depends on the store: a [`DiskStore`] moves
    /// spilled state out of process entirely, while the default
    /// [`MemStore`] only shrinks it to the O(T) observations + summary
    /// snapshot (~30× smaller at D = 4, but still in RAM) — deploy a
    /// disk store before relying on the watermark as a hard memory
    /// bound.
    pub resident_watermark: usize,
    /// Hard ceiling on *registered* sessions (any residency) — a
    /// denial-of-service backstop, not a sizing knob: even spilled
    /// sessions cost a registry entry and store state, so an unchecked
    /// open loop would still exhaust memory/disk. Well above
    /// `resident_watermark` by default; opens beyond it get a typed
    /// rejection. Size it to your spill target: with the in-memory
    /// [`MemStore`] every spilled session still holds its observations
    /// + snapshot in process RAM, so this ceiling *is* the memory bound
    /// — set it accordingly (a [`DiskStore`] moves that state to disk
    /// and can afford a much larger ceiling).
    pub max_open_sessions: usize,
    /// Durable session-store directory. `Some(dir)` backs sessions with
    /// a [`DiskStore`] (append-ahead logs; [`Coordinator::recover_sessions`]
    /// replays them after a crash). `None` uses the in-memory
    /// [`MemStore`]: eviction still frees resident RAM, but nothing
    /// survives the process.
    pub session_store: Option<PathBuf>,
    /// Observations appended to a session between automatic
    /// checkpoint-compaction cycles of its log — bounds both the log
    /// length and the append-replay cost of a restore.
    pub checkpoint_every: usize,
    /// Run watermark spills and checkpoint compactions on a background
    /// housekeeping worker (the default): a burst of opens never pays
    /// snapshot/serde cost in-band, at the price of residency
    /// transiently overshooting the watermark until the worker catches
    /// up (the `max_open_sessions` backstop still bounds the registry).
    /// `false` restores the in-band behavior: every verb re-imposes the
    /// watermark synchronously before returning.
    pub housekeeping: bool,
    /// Bounded depth of the housekeeping work queue. A full queue drops
    /// new nudges rather than blocking the serve path — safe, because
    /// every queued task ends with a watermark pass, so pending work
    /// already covers the dropped intent.
    pub housekeeping_queue: usize,
    /// Group-commit deadline window for the disk store's append fsyncs
    /// (see `store::disk`): appends from concurrent sessions inside one
    /// window share fsyncs, acked only after their covering sync.
    /// `Duration::ZERO` fsyncs inline per append. Ignored by non-disk
    /// stores.
    pub group_commit_window: Duration,
    /// Resident-RAM *byte* budget across all resident element chains,
    /// each session weighted by T·D²·8 bytes (its chain estimate) — so
    /// eviction sheds one giant session instead of many small ones.
    /// Enforced alongside the count watermark; `usize::MAX` disables.
    /// Never spills the last resident session (a lone over-budget
    /// session would otherwise thrash spill/restore on every touch).
    pub resident_bytes_watermark: usize,
    /// Optional event timeline: every session transition (open, append,
    /// spill, restore, close, release, recover) is appended to it as a
    /// durable record. `None` (the default) disables emission entirely;
    /// with a timeline, recording is non-blocking — `obs::Timeline`
    /// drops events on a full channel rather than stalling the serve
    /// path. Share one timeline with
    /// [`crate::net::NetServerConfig::timeline`] to interleave
    /// connection and session events in a single monotonic log.
    pub timeline: Option<Arc<Timeline>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifacts: {
                let dir = crate::runtime::artifacts_dir();
                dir.join("manifest.json").exists().then_some(dir)
            },
            xla_workers: 4,
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            scan: ScanOptions::default(),
            max_stream_lag: 4096,
            resident_watermark: 1024,
            max_open_sessions: 1 << 16,
            session_store: None,
            checkpoint_every: 4096,
            housekeeping: true,
            housekeeping_queue: 64,
            group_commit_window: DEFAULT_GROUP_COMMIT_WINDOW,
            resident_bytes_watermark: usize::MAX,
            timeline: None,
        }
    }
}

impl CoordinatorConfig {
    /// Native-only configuration (no artifacts required).
    pub fn native_only() -> Self {
        Self { artifacts: None, ..Default::default() }
    }
}

/// The inference service.
///
/// All native execution dispatches through one [`Engine`] per registered
/// model (serialized by a per-model mutex so the engine's scratch
/// workspace is reused across requests); the PJRT core-artifact path
/// dispatches through the engine's [`XlaBackend`].
pub struct Coordinator {
    manifest: Option<Arc<Manifest>>,
    pool: Option<Arc<XlaPool>>,
    xla: Option<XlaBackend>,
    router: Router,
    models: RwLock<BTreeMap<String, ModelEntry>>,
    /// Linear-Gaussian model registry — the Kalman tier's sibling of
    /// `models`. A separate map (rather than a sum type in `models`)
    /// keeps every decode path untouched: only session verbs with
    /// `SessionKind::Kalman` consult it. `Lgssm` inference is stateless
    /// per call, so no per-model engine/mutex pair is needed — sessions
    /// build a throwaway [`KalmanEngine`] around the shared `Arc`.
    lgssms: RwLock<BTreeMap<String, Arc<Lgssm>>>,
    /// The session maps, gauges and spill/restore machinery — shared
    /// with the housekeeping worker, which holds its own `Arc`.
    registry: Arc<SessionRegistry>,
    /// Background spill/compaction worker; `None` runs housekeeping
    /// in-band on the serve path (`CoordinatorConfig::housekeeping`).
    housekeeper: Option<Housekeeper>,
    next_session: AtomicU64,
    max_stream_lag: usize,
    max_open_sessions: usize,
    /// Spill/restore/recovery backend — always a clone of
    /// `registry.store` (kept here so the serve path doesn't chase two
    /// pointers); constructors must set both from the same Arc.
    store: Arc<dyn SessionStore>,
    /// Always a clone of `registry.metrics` (same invariant).
    metrics: Arc<Metrics>,
    scan: ScanOptions,
    batcher_config: BatcherConfig,
}

/// One registered model: the Hmm and its serving engine, paired in a
/// single map entry so a concurrent re-registration can never match a
/// new model with a stale engine (or vice versa).
#[derive(Clone)]
struct ModelEntry {
    hmm: Arc<Hmm>,
    engine: Arc<Mutex<Engine>>,
}

/// The model a session was opened against — discrete or
/// linear-Gaussian. Mirrors the session's own internal model reference:
/// exactly one variant per session kind, fixed at open.
#[derive(Clone)]
enum ModelHandle {
    /// A discrete HMM (every non-Kalman [`SessionKind`]).
    Hmm(Arc<Hmm>),
    /// A linear-Gaussian state-space model ([`SessionKind::Kalman`]).
    Lgssm(Arc<Lgssm>),
}

impl ModelHandle {
    /// The discrete model, on paths only discrete sessions reach (the
    /// fixed-lag window hint — Kalman sessions are filtering-only, so
    /// `lag > 0` implies the Hmm variant).
    fn hmm(&self) -> &Arc<Hmm> {
        match self {
            ModelHandle::Hmm(h) => h,
            ModelHandle::Lgssm(_) => {
                unreachable!("discrete model handle on a Kalman session")
            }
        }
    }
}

/// One open streaming session: its residency slot plus the model handle
/// (for the router's window hints) and the durable meta (open options +
/// fixed-lag width) the store needs to re-create it.
struct SessionEntry {
    slot: Mutex<SessionSlot>,
    model: ModelHandle,
    meta: SessionMeta,
    /// LRU stamp: coordinator clock at the last open/append/close touch.
    /// Written only inside the registry's `lru`-locked helpers, so the
    /// ordered index and the stamp can never disagree.
    touch: AtomicU64,
    /// Residency hint readable without the slot lock (eviction scans).
    resident: AtomicBool,
    /// Observations appended since the last log compaction.
    since_ckpt: AtomicU64,
    /// A checkpoint-compaction request for this session is already on
    /// the housekeeping queue (dedupes repeated nudges while one is in
    /// flight).
    ckpt_pending: AtomicBool,
    /// Resident bytes currently charged against the byte-budget
    /// watermark (T·D²·8 at the last push/restore; 0 while evicted).
    charged: AtomicUsize,
}

/// Residency state of a session.
enum SessionSlot {
    /// Element chain in RAM, ready to serve.
    Resident(Session),
    /// Spilled to the store; `len` observations are durably logged.
    Evicted { len: usize },
}

/// The session-registry core shared by the serve path and the
/// housekeeping worker: the session map, the ordered LRU index, the
/// residency gauges, and the spill/restore machinery. Everything here
/// takes `&self` — the coordinator and the worker hold separate `Arc`s.
struct SessionRegistry {
    /// Streaming sessions, keyed like the per-model engine map: each
    /// entry owns its mutex-serialized slot (resident `engine::Session`
    /// or an evicted stub restorable from the store).
    sessions: RwLock<BTreeMap<u64, Arc<SessionEntry>>>,
    /// Ordered `(touch, id)` index over the *resident* sessions,
    /// maintained on every touch/spill/restore — victim selection pops
    /// its first live entry in O(log n), replacing the O(n) session-map
    /// scan. Lock order: `sessions` (if held) before `lru`; `lru` is
    /// never held across a slot lock or store call.
    lru: Mutex<BTreeSet<(u64, u64)>>,
    /// Logical LRU clock, bumped on every session touch.
    clock: AtomicU64,
    /// Gauge: sessions whose element chains are resident right now.
    resident: AtomicUsize,
    /// Gauge: estimated resident element-chain bytes (Σ T·D²·8).
    resident_bytes: AtomicUsize,
    /// Spill/restore/recovery backend (disk or in-memory).
    store: Arc<dyn SessionStore>,
    metrics: Arc<Metrics>,
    scan: ScanOptions,
    resident_watermark: usize,
    resident_bytes_watermark: usize,
    /// Observations between checkpoint compactions (≥ 1).
    checkpoint_every: usize,
    /// Optional event timeline; session transitions land here. Lives on
    /// the registry (not the coordinator) because spills and restores
    /// are driven by the housekeeping worker, which only holds the
    /// registry.
    timeline: Option<Arc<Timeline>>,
}

impl SessionRegistry {
    /// Append an event to the timeline (no-op without one; never
    /// blocks — a full channel drops the event and bumps a counter).
    fn record(&self, event: TimelineEvent) {
        if let Some(timeline) = &self.timeline {
            timeline.record(event);
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    fn entry(&self, id: u64) -> Result<Arc<SessionEntry>> {
        self.sessions
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::invalid_request(format!("unknown session {id}")))
    }

    /// Stamp a fresh touch and re-key the LRU index entry (resident
    /// sessions only — evicted ones are not indexed).
    fn touch(&self, id: u64, entry: &SessionEntry) {
        let mut lru = self.lru.lock().unwrap();
        let now = self.tick();
        let old = entry.touch.swap(now, Ordering::Relaxed);
        if entry.resident.load(Ordering::Relaxed) {
            lru.remove(&(old, id));
            lru.insert((now, id));
        }
    }

    /// Flip `entry` resident (idempotent): gauge, flag and index move
    /// together under the `lru` lock, so a racing touch can never leave
    /// a stale index key behind.
    fn note_resident(&self, id: u64, entry: &SessionEntry) {
        let mut lru = self.lru.lock().unwrap();
        if !entry.resident.swap(true, Ordering::Relaxed) {
            self.resident.fetch_add(1, Ordering::Relaxed);
            lru.insert((entry.touch.load(Ordering::Relaxed), id));
        }
    }

    /// Flip `entry` evicted (idempotent; the swap guard keeps a
    /// close/spill race from double-decrementing the gauge) and release
    /// its byte charge.
    fn note_evicted(&self, id: u64, entry: &SessionEntry) {
        {
            let mut lru = self.lru.lock().unwrap();
            if entry.resident.swap(false, Ordering::Relaxed) {
                self.resident.fetch_sub(1, Ordering::Relaxed);
                lru.remove(&(entry.touch.load(Ordering::Relaxed), id));
            }
        }
        let old = entry.charged.swap(0, Ordering::Relaxed);
        self.resident_bytes.fetch_sub(old, Ordering::Relaxed);
    }

    /// Re-estimate a resident session's byte charge after its length
    /// changed (called under the session's slot lock). `len` is the
    /// session's observation count — symbols for discrete families,
    /// encoded u32 words for Kalman (two per f64 observation value).
    fn recharge(&self, entry: &SessionEntry, len: usize) {
        let new = match &entry.model {
            // Discrete chains retain one D×D element per symbol.
            ModelHandle::Hmm(hmm) => {
                let d = hmm.num_states();
                len.saturating_mul(d.saturating_mul(d).saturating_mul(8))
            }
            // Kalman chains retain one element per observation *row*
            // (len / words_per_step rows): three n×n matrices plus two
            // n-vectors of f64 each.
            ModelHandle::Lgssm(m) => {
                let n = m.state_dim();
                let per_row = (3 * n * n + 2 * n).saturating_mul(8);
                (len / m.words_per_step().max(1)).saturating_mul(per_row)
            }
        };
        let old = entry.charged.swap(new, Ordering::Relaxed);
        if new >= old {
            self.resident_bytes.fetch_add(new - old, Ordering::Relaxed);
        } else {
            self.resident_bytes.fetch_sub(old - new, Ordering::Relaxed);
        }
    }

    /// Whether eviction has work: the count watermark is breached, or
    /// the byte budget is (never counting a lone resident session —
    /// spilling it would thrash restore/spill on every touch).
    fn over_watermark(&self) -> bool {
        let resident = self.resident.load(Ordering::Relaxed);
        resident > self.resident_watermark
            || (resident > 1
                && self.resident_bytes.load(Ordering::Relaxed)
                    > self.resident_bytes_watermark)
    }

    /// Least-recently-touched resident session other than `protect`:
    /// the first live entry of the ordered index (stale keys met on the
    /// way — closed or already-spilled sessions — are swept out).
    fn pick_victim(
        &self,
        protect: Option<u64>,
    ) -> Option<(u64, Arc<SessionEntry>)> {
        let sessions = self.sessions.read().unwrap();
        let mut lru = self.lru.lock().unwrap();
        let mut stale = Vec::new();
        let mut found = None;
        for &(touch, id) in lru.iter() {
            if Some(id) == protect {
                continue;
            }
            match sessions.get(&id) {
                Some(e) if e.resident.load(Ordering::Relaxed) => {
                    found = Some((id, Arc::clone(e)));
                    break;
                }
                _ => stale.push((touch, id)),
            }
        }
        for key in stale {
            lru.remove(&key);
        }
        found
    }

    /// Restore an evicted session into its slot (no-op when resident):
    /// resume from the stored checkpoint snapshot (bit-identical — the
    /// `elements::serde` round-trip is exact) and replay the appends
    /// logged after it. Called under the session's slot lock.
    fn make_resident(
        &self,
        id: u64,
        entry: &SessionEntry,
        slot: &mut SessionSlot,
    ) -> Result<()> {
        if matches!(slot, SessionSlot::Resident(_)) {
            return Ok(());
        }
        let t0 = Instant::now();
        let stored = self.store.restore(id)?;
        // Restore against the session's *original* model handle — never
        // the registry's current entry, which a re-registration may have
        // replaced. Resident sessions keep their model Arc across
        // re-registration; evicted ones must behave identically, or
        // eviction stops being transparent.
        let mut session = match &entry.model {
            ModelHandle::Hmm(hmm) => {
                let engine = Engine::builder(Arc::clone(hmm))
                    .scan_options(self.scan)
                    .build();
                match &stored.snapshot {
                    Some(snap) => engine.resume_session(snap)?,
                    None => engine.open_session(entry.meta.options),
                }
            }
            ModelHandle::Lgssm(m) => {
                let engine = KalmanEngine::from_arc(Arc::clone(m))
                    .with_scan_options(self.scan);
                match &stored.snapshot {
                    Some(snap) => engine.resume_session(snap)?,
                    None => engine.open_session(entry.meta.options),
                }
            }
        };
        for chunk in &stored.appends {
            session.push(chunk)?;
        }
        let len = session.len();
        *slot = SessionSlot::Resident(session);
        self.note_resident(id, entry);
        self.recharge(entry, len);
        self.metrics.on_restore(t0.elapsed());
        self.record(TimelineEvent::Restore { session: id, len });
        Ok(())
    }

    /// Demote one resident session to the store: snapshot → compacted
    /// log → drop the in-RAM chain. No-op when already evicted. An
    /// append racing this spill queues behind the slot lock and
    /// restores on entry — it can never observe a half-spilled chain.
    fn spill_session(&self, id: u64, entry: &SessionEntry) -> Result<()> {
        let mut slot = entry.slot.lock().expect("session mutex poisoned");
        let SessionSlot::Resident(session) = &mut *slot else {
            return Ok(());
        };
        let len = session.len();
        self.store.compact(id, &entry.meta, &session.snapshot())?;
        entry.since_ckpt.store(0, Ordering::Relaxed);
        *slot = SessionSlot::Evicted { len };
        self.note_evicted(id, entry);
        self.metrics.on_spill();
        self.record(TimelineEvent::Spill { session: id, len });
        Ok(())
    }

    /// Checkpoint-compact one session's log in the background (the
    /// housekeeping twin of the old in-band compaction). Evicted
    /// sessions are skipped — the spill already compacted them.
    fn compact_session(&self, id: u64, entry: &SessionEntry) {
        let mut slot = entry.slot.lock().expect("session mutex poisoned");
        if let SessionSlot::Resident(session) = &mut *slot {
            // Best-effort: a failed compaction leaves the (longer but
            // valid) log in place; since_ckpt keeps growing, so a later
            // append re-requests it.
            if self.store.compact(id, &entry.meta, &session.snapshot()).is_ok() {
                entry.since_ckpt.store(0, Ordering::Relaxed);
            }
        }
        entry.ckpt_pending.store(false, Ordering::Relaxed);
    }

    /// Watermark-driven eviction: while residency exceeds the count or
    /// byte watermark, spill the least-recently-touched session (never
    /// `protect` — the session serving the current verb, in-band mode).
    fn enforce_watermark(&self, protect: Option<u64>) {
        while self.over_watermark() {
            let Some((id, entry)) = self.pick_victim(protect) else { break };
            if self.spill_session(id, &entry).is_err() {
                // Store failure: stop evicting and keep serving from RAM
                // rather than dropping state.
                break;
            }
        }
    }
}

/// Work items for the housekeeping worker.
enum HkTask {
    /// Re-impose the residency watermarks (spill victims as needed).
    Enforce,
    /// Checkpoint-compact one session's append-ahead log.
    Compact(u64),
    /// Reply once everything queued before this task (plus a final
    /// watermark pass) has completed — the quiesce barrier.
    Quiesce(mpsc::Sender<()>),
}

/// The background housekeeping worker: one thread draining a bounded
/// queue of spill/compaction work so the serve path never pays
/// snapshot-serde or compaction fsyncs in-band. Dropping it closes the
/// queue and joins the thread.
struct Housekeeper {
    tx: Option<mpsc::SyncSender<HkTask>>,
    join: Option<thread::JoinHandle<()>>,
}

impl Housekeeper {
    fn spawn(registry: Arc<SessionRegistry>, queue: usize) -> Housekeeper {
        let (tx, rx) = mpsc::sync_channel::<HkTask>(queue.max(1));
        let join = thread::Builder::new()
            .name("hmm-scan-housekeeper".into())
            .spawn(move || {
                while let Ok(task) = rx.recv() {
                    match task {
                        HkTask::Enforce => {
                            registry.enforce_watermark(None);
                            registry.metrics.on_hk_completed();
                        }
                        HkTask::Compact(id) => {
                            if let Ok(entry) = registry.entry(id) {
                                registry.compact_session(id, &entry);
                            }
                            // Every task ends with a watermark pass, so
                            // a nudge dropped on a full queue is still
                            // covered by whatever was already queued.
                            registry.enforce_watermark(None);
                            registry.metrics.on_hk_completed();
                        }
                        HkTask::Quiesce(done) => {
                            registry.enforce_watermark(None);
                            let _ = done.send(());
                        }
                    }
                }
            })
            .expect("spawn housekeeper");
        Housekeeper { tx: Some(tx), join: Some(join) }
    }

    /// Non-blocking enqueue; `false` when the bounded queue is full.
    fn submit(&self, task: HkTask) -> bool {
        self.tx
            .as_ref()
            .expect("housekeeper shut down")
            .try_send(task)
            .is_ok()
    }

    /// Block until the worker has drained everything queued so far and
    /// run a final watermark pass.
    fn quiesce(&self) {
        let (done_tx, done_rx) = mpsc::channel();
        let sent = self
            .tx
            .as_ref()
            .expect("housekeeper shut down")
            .send(HkTask::Quiesce(done_tx))
            .is_ok();
        if sent {
            let _ = done_rx.recv();
        }
    }
}

impl Drop for Housekeeper {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Coordinator {
    /// Build a coordinator: XLA pool (when artifacts are configured),
    /// session store (disk-backed when `session_store` is set, with
    /// group commit wired to the metrics), registry, and — unless
    /// disabled — the background housekeeping worker.
    pub fn new(config: CoordinatorConfig) -> Result<Self> {
        let (manifest, pool) = match &config.artifacts {
            Some(dir) => {
                let manifest = Arc::new(Manifest::load(dir)?);
                let pool = Arc::new(XlaPool::new(dir.clone(), config.xla_workers)?);
                (Some(manifest), Some(pool))
            }
            None => (None, None),
        };
        let xla = match (&manifest, &pool) {
            (Some(m), Some(p)) => {
                let exec: Arc<dyn ArtifactExec + Send + Sync> = Arc::clone(p);
                Some(XlaBackend::new(exec, Arc::clone(m)))
            }
            _ => None,
        };
        let metrics = Arc::new(Metrics::new());
        if let Some(tl) = &config.timeline {
            // Surface the timeline's own health (seq / drops / segment
            // count) on this coordinator's scrape.
            metrics.attach_timeline(Arc::clone(tl));
        }
        let store: Arc<dyn SessionStore> = match &config.session_store {
            Some(dir) => {
                let mut disk = DiskStore::open(dir.clone())?
                    .with_group_commit_window(config.group_commit_window);
                let m = Arc::clone(&metrics);
                disk.set_sync_observer(move |files, records| {
                    m.on_sync_batch(files, records)
                });
                if let Some(tl) = &config.timeline {
                    // Attribute each append's blocked-on-fsync time (the
                    // group-commit wait) to the ambient request span —
                    // the observer runs on the appending thread, where
                    // that context lives.
                    let tl = Arc::clone(tl);
                    disk.set_wait_observer(move |elapsed| {
                        crate::obs::span::annotate(
                            Some(&tl),
                            "sync-wait",
                            elapsed,
                        )
                    });
                }
                Arc::new(disk)
            }
            None => Arc::new(MemStore::new()),
        };
        // Seed the id allocator past everything the store already holds:
        // a fresh open must never reuse — and `create`-overwrite the
        // durable log of — a crashed session's id, even when the
        // operator serves opens before calling `recover_sessions`.
        let first_free_id = store.max_id()?.unwrap_or(0);
        let registry = Arc::new(SessionRegistry {
            sessions: RwLock::new(BTreeMap::new()),
            lru: Mutex::new(BTreeSet::new()),
            clock: AtomicU64::new(0),
            resident: AtomicUsize::new(0),
            resident_bytes: AtomicUsize::new(0),
            store: Arc::clone(&store),
            metrics: Arc::clone(&metrics),
            scan: config.scan,
            resident_watermark: config.resident_watermark,
            resident_bytes_watermark: config.resident_bytes_watermark,
            checkpoint_every: config.checkpoint_every.max(1),
            timeline: config.timeline.clone(),
        });
        let housekeeper = config.housekeeping.then(|| {
            Housekeeper::spawn(Arc::clone(&registry), config.housekeeping_queue)
        });
        Ok(Self {
            manifest,
            pool,
            xla,
            router: Router::new(config.router),
            models: RwLock::new(BTreeMap::new()),
            lgssms: RwLock::new(BTreeMap::new()),
            registry,
            housekeeper,
            next_session: AtomicU64::new(first_free_id),
            max_stream_lag: config.max_stream_lag,
            max_open_sessions: config.max_open_sessions,
            store,
            metrics,
            scan: config.scan,
            batcher_config: config.batcher,
        })
    }

    /// Register (or replace) a servable model under `id`, building its
    /// dedicated engine with the coordinator's scan options.
    pub fn register_model(&self, id: impl Into<String>, hmm: Hmm) {
        let hmm = Arc::new(hmm);
        let engine = Engine::builder(Arc::clone(&hmm))
            .scan_options(self.scan)
            .build();
        let entry = ModelEntry { hmm, engine: Arc::new(Mutex::new(engine)) };
        self.models.write().unwrap().insert(id.into(), entry);
    }

    /// Register (or replace) a linear-Gaussian model under `id` for
    /// [`SessionKind::Kalman`] streaming sessions. The namespace is
    /// separate from [`register_model`](Self::register_model)'s —
    /// the session kind picks the registry, so an HMM and an `Lgssm`
    /// may share a name without ambiguity.
    pub fn register_lgssm(&self, id: impl Into<String>, model: Lgssm) {
        self.lgssms.write().unwrap().insert(id.into(), Arc::new(model));
    }

    fn entry(&self, id: &str) -> Result<ModelEntry> {
        self.models
            .read()
            .unwrap()
            .get(id)
            .cloned()
            .ok_or_else(|| Error::invalid_request(format!("unknown model '{id}'")))
    }

    fn lgssm_entry(&self, id: &str) -> Result<Arc<Lgssm>> {
        self.lgssms
            .read()
            .unwrap()
            .get(id)
            .cloned()
            .ok_or_else(|| {
                Error::invalid_request(format!(
                    "unknown linear-Gaussian model '{id}'"
                ))
            })
    }

    /// Look up a registered model by id.
    pub fn model(&self, id: &str) -> Result<Arc<Hmm>> {
        Ok(self.entry(id)?.hmm)
    }

    /// Look up a registered linear-Gaussian model by id.
    pub fn lgssm(&self, id: &str) -> Result<Arc<Lgssm>> {
        self.lgssm_entry(id)
    }

    /// The serving metrics (counters, gauges, latency percentiles).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The loaded artifact manifest, when PJRT serving is enabled.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Resolve the plan a request would execute (exposed for tests/CLI).
    pub fn plan_for(&self, req: &DecodeRequest) -> Result<ExecutionPlan> {
        let hmm = self.model(&req.model)?;
        hmm.check_observations(&req.ys)?;
        self.router.plan(
            self.manifest.as_deref(),
            req,
            hmm.num_states(),
            hmm.num_symbols(),
        )
    }

    /// Serve one request synchronously.
    pub fn decode(&self, req: DecodeRequest) -> Result<DecodeResponse> {
        self.metrics.on_request();
        let start = Instant::now();
        let result = self.execute(&req);
        match result {
            Ok((result, plan)) => {
                let elapsed = start.elapsed();
                self.metrics.on_complete(elapsed);
                Ok(DecodeResponse { id: req.id, result, plan, elapsed })
            }
            Err(e) => {
                self.metrics.on_failure();
                Err(e)
            }
        }
    }

    /// Serve a group of requests through the batcher: requests that
    /// resolve to the same artifact are dispatched back-to-back so the
    /// XLA pool executes them concurrently.
    pub fn decode_many(
        &self,
        reqs: Vec<DecodeRequest>,
    ) -> Vec<Result<DecodeResponse>> {
        let mut batcher: Batcher<(usize, DecodeRequest)> =
            Batcher::new(self.batcher_config);
        let now = Instant::now();
        let mut batches = Vec::new();
        for (idx, req) in reqs.into_iter().enumerate() {
            let key = match self.plan_for(&req) {
                Ok(plan) => plan_key(&plan),
                Err(_) => "invalid".to_string(), // decode() reports the error
            };
            if let Some(b) = batcher.push(&key, (idx, req), now) {
                batches.push(b);
            }
        }
        batches.extend(batcher.flush_all());

        let mut out: Vec<Option<Result<DecodeResponse>>> = Vec::new();
        for batch in &batches {
            self.metrics.on_batch(batch.items.len());
            out.resize_with(
                out.len().max(batch.items.iter().map(|(i, _)| i + 1).max().unwrap_or(0)),
                || None,
            );
        }
        for batch in batches {
            for (idx, req) in batch.items {
                let resp = self.decode(req);
                if idx >= out.len() {
                    out.resize_with(idx + 1, || None);
                }
                out[idx] = Some(resp);
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(Error::coordinator("lost request"))))
            .collect()
    }

    /// Serve one streaming verb synchronously (open / append / stat /
    /// close — see [`StreamVerb`]). Appends return the filtering
    /// marginal, and a fixed-lag smoothing window when the session was
    /// opened with `lag` > 0 — restoring the session from the store
    /// first when it was evicted; stat reports residency without
    /// restoring; close returns the exact full-sequence posterior
    /// (bit-identical to the one-shot parallel smoother under the
    /// session's scan options) and removes the session everywhere.
    pub fn stream(&self, req: StreamRequest) -> Result<StreamResponse> {
        let start = Instant::now();
        match self.stream_verb(req.verb, start) {
            Ok(reply) => {
                Ok(StreamResponse { id: req.id, reply, elapsed: start.elapsed() })
            }
            Err(e) => {
                self.metrics.on_failure();
                Err(e)
            }
        }
    }

    /// The serve-cost guards every new session must satisfy — shared by
    /// `Open`, `OpenAt` and (against the imported meta) `Import`.
    fn check_session_limits(
        &self,
        options: &crate::engine::SessionOptions,
        lag: usize,
    ) -> Result<()> {
        if lag > self.max_stream_lag {
            return Err(Error::invalid_request(format!(
                "requested lag {lag} exceeds the configured maximum {}",
                self.max_stream_lag
            )));
        }
        // The append cost is O(lag + block), so the block is capped
        // alongside the lag — otherwise a huge client block re-opens
        // the degrade-every-append hole the lag cap closes.
        let max_block =
            self.max_stream_lag.max(crate::engine::DEFAULT_SESSION_BLOCK);
        if options.block.is_some_and(|b| b > max_block) {
            return Err(Error::invalid_request(format!(
                "requested block {} exceeds the maximum {max_block}",
                options.block.unwrap_or(0)
            )));
        }
        if options.kind == SessionKind::Bayes && lag > 0 {
            return Err(Error::invalid_request(
                "bayes sessions are filtering-only: open with lag = 0",
            ));
        }
        if options.kind == SessionKind::Kalman && lag > 0 {
            return Err(Error::invalid_request(
                "kalman sessions are filtering-only: open with lag = 0",
            ));
        }
        Ok(())
    }

    /// Publish a freshly built resident session under `id`: gauge, map
    /// insert (rejecting an already-registered id), LRU index, durable
    /// open record — with full rollback on any failure. Shared by
    /// `Open`, `OpenAt` and `Import`.
    fn publish_session(
        &self,
        id: u64,
        model: ModelHandle,
        meta: SessionMeta,
        session: Session,
    ) -> Result<Arc<SessionEntry>> {
        let sess_entry = Arc::new(SessionEntry {
            slot: Mutex::new(SessionSlot::Resident(session)),
            model,
            meta,
            touch: AtomicU64::new(self.registry.tick()),
            resident: AtomicBool::new(true),
            since_ckpt: AtomicU64::new(0),
            ckpt_pending: AtomicBool::new(false),
            charged: AtomicUsize::new(0),
        });
        // Count the residency *before* the entry is published:
        // a concurrent eviction scan may spill it the moment it
        // appears in the map, and its swap-guarded decrement
        // must never land on a gauge that has not yet been
        // incremented (usize wrap → permanent eviction churn).
        self.registry.resident.fetch_add(1, Ordering::Relaxed);
        {
            // DoS backstop, checked atomically with the insert:
            // even spilled sessions cost a registry entry + store
            // state, so total opens stay bounded (the watermark
            // only bounds *residency*).
            let mut sessions = self.registry.sessions.write().unwrap();
            if sessions.len() >= self.max_open_sessions {
                drop(sessions);
                self.registry.resident.fetch_sub(1, Ordering::Relaxed);
                return Err(Error::invalid_request(format!(
                    "open session limit {} reached",
                    self.max_open_sessions
                )));
            }
            // Caller-chosen ids (`OpenAt` / `Import`) may collide with
            // a live session; never overwrite it. Allocator-chosen ids
            // cannot collide (the allocator is seeded past the store
            // and advanced past every explicit id).
            if sessions.contains_key(&id) {
                drop(sessions);
                self.registry.resident.fetch_sub(1, Ordering::Relaxed);
                return Err(Error::invalid_request(format!(
                    "session {id} already exists"
                )));
            }
            sessions.insert(id, Arc::clone(&sess_entry));
        }
        // Index the new resident for O(log n) victim selection.
        // This three-step publish (gauge above, map insert,
        // index insert) intentionally bypasses `note_resident`:
        // the flag is already true, and the id is unreachable
        // to other verbs until the reply below — keep it that
        // way if these steps are ever reordered, or the
        // gauge/flag/index-move-together invariant of the
        // registry helpers stops holding.
        self.registry.lru.lock().unwrap().insert((
            sess_entry.touch.load(Ordering::Relaxed),
            id,
        ));
        // Durable open record before the id is revealed to the
        // client (the entry is registered but unreachable until
        // the reply); a create failure rolls the open back.
        if let Err(e) = self.store.create(id, &sess_entry.meta) {
            self.registry.sessions.write().unwrap().remove(&id);
            self.registry.note_evicted(id, &sess_entry);
            return Err(e);
        }
        self.metrics.on_session_open();
        Ok(sess_entry)
    }

    /// Resolve the model a new session binds to and build its resident
    /// [`Session`] plus model fingerprint — branching on the requested
    /// kind (`SessionKind::Kalman` opens against the linear-Gaussian
    /// registry, everything else against the HMM registry). Shared by
    /// `Open` and `OpenAt`.
    fn build_session(
        &self,
        model_id: &str,
        options: crate::engine::SessionOptions,
    ) -> Result<(ModelHandle, Session, u64)> {
        if options.kind == SessionKind::Kalman {
            let m = self.lgssm_entry(model_id)?;
            let engine = KalmanEngine::from_arc(Arc::clone(&m))
                .with_scan_options(self.scan);
            let session = engine.open_session(options);
            let fp = lgssm_fingerprint(&m);
            Ok((ModelHandle::Lgssm(m), session, fp))
        } else {
            let entry = self.entry(model_id)?;
            let session = {
                let engine =
                    entry.engine.lock().expect("engine mutex poisoned");
                engine.open_session(options)
            };
            let fp = model_fingerprint(&entry.hmm);
            Ok((ModelHandle::Hmm(entry.hmm), session, fp))
        }
    }

    fn stream_verb(&self, verb: StreamVerb, start: Instant) -> Result<StreamReply> {
        match verb {
            StreamVerb::Open { model, options, lag } => {
                self.check_session_limits(&options, lag)?;
                let (handle, session, fp) =
                    self.build_session(&model, options)?;
                let id = self.next_session.fetch_add(1, Ordering::Relaxed) + 1;
                let meta =
                    SessionMeta { model, options, lag, fingerprint: Some(fp) };
                let entry = self.publish_session(id, handle, meta, session)?;
                self.registry.record(TimelineEvent::SessionOpen {
                    session: id,
                    model: entry.meta.model.clone(),
                    len: 0,
                });
                self.kick_housekeeping(Some(id));
                Ok(StreamReply::Opened { session: id })
            }
            StreamVerb::OpenAt { session: id, model, options, lag } => {
                self.check_session_limits(&options, lag)?;
                let (handle, session, fp) =
                    self.build_session(&model, options)?;
                // Advance the allocator past the explicit id so a later
                // local `Open` can never collide with (and overwrite
                // the durable log of) a router-placed session.
                self.next_session.fetch_max(id, Ordering::Relaxed);
                let meta =
                    SessionMeta { model, options, lag, fingerprint: Some(fp) };
                let entry = self.publish_session(id, handle, meta, session)?;
                self.registry.record(TimelineEvent::SessionOpen {
                    session: id,
                    model: entry.meta.model.clone(),
                    len: 0,
                });
                self.kick_housekeeping(Some(id));
                Ok(StreamReply::Opened { session: id })
            }
            StreamVerb::Export { session } => {
                let entry = self.session_entry(session)?;
                let reply = (|| -> Result<StreamReply> {
                    let mut slot =
                        entry.slot.lock().expect("session mutex poisoned");
                    self.registry.make_resident(session, &entry, &mut slot)?;
                    let SessionSlot::Resident(s) = &mut *slot else {
                        unreachable!("make_resident")
                    };
                    // The snapshot alone re-creates the session
                    // bit-identically (the spill/restore contract), so
                    // no append tail needs to travel with it.
                    Ok(StreamReply::Exported {
                        session,
                        len: s.len(),
                        meta: entry.meta.clone(),
                        snapshot: s.snapshot(),
                    })
                })();
                self.registry.touch(session, &entry);
                // The export may have just restored the session —
                // re-impose the watermark either way.
                self.kick_housekeeping(Some(session));
                reply
            }
            StreamVerb::Import { session: id, meta, snapshot } => {
                self.check_session_limits(&meta.options, meta.lag)?;
                // Refuse to bind an exported snapshot to a *different*
                // model registered under the same name — resume trusts
                // the snapshot's summaries (same rule as recovery). The
                // per-kind fingerprint spaces are disjoint, so a Kalman
                // snapshot can never sneak past the check onto an HMM.
                let (handle, session) =
                    if meta.options.kind == SessionKind::Kalman {
                        let m = self.lgssm_entry(&meta.model)?;
                        if let Some(fp) = meta.fingerprint {
                            if fp != lgssm_fingerprint(&m) {
                                return Err(Error::invalid_request(format!(
                                    "import: model '{}' fingerprint mismatch",
                                    meta.model
                                )));
                            }
                        }
                        let engine = KalmanEngine::from_arc(Arc::clone(&m))
                            .with_scan_options(self.scan);
                        let session = engine.resume_session(&snapshot)?;
                        (ModelHandle::Lgssm(m), session)
                    } else {
                        let entry = self.entry(&meta.model)?;
                        if let Some(fp) = meta.fingerprint {
                            if fp != model_fingerprint(&entry.hmm) {
                                return Err(Error::invalid_request(format!(
                                    "import: model '{}' fingerprint mismatch",
                                    meta.model
                                )));
                            }
                        }
                        let engine = Engine::builder(Arc::clone(&entry.hmm))
                            .scan_options(self.scan)
                            .build();
                        let session = engine.resume_session(&snapshot)?;
                        (ModelHandle::Hmm(entry.hmm), session)
                    };
                let len = session.len();
                self.next_session.fetch_max(id, Ordering::Relaxed);
                let sess_entry =
                    self.publish_session(id, handle, meta, session)?;
                // Persist the imported state immediately: the open
                // record alone would make a crash-recovered session
                // come back *empty*. A compact failure rolls the import
                // back (the source still holds the session).
                if let Err(e) =
                    self.store.compact(id, &sess_entry.meta, &snapshot)
                {
                    if self
                        .registry
                        .sessions
                        .write()
                        .unwrap()
                        .remove(&id)
                        .is_some()
                    {
                        self.registry.note_evicted(id, &sess_entry);
                        let _ = self.store.remove(id);
                        self.metrics.on_session_close();
                    }
                    return Err(e);
                }
                // Recorded only after the compact above: a rolled-back
                // import must not leave an open event with no close.
                self.registry.record(TimelineEvent::SessionOpen {
                    session: id,
                    model: sess_entry.meta.model.clone(),
                    len,
                });
                self.kick_housekeeping(Some(id));
                Ok(StreamReply::Imported { session: id, len })
            }
            StreamVerb::Release { session } => {
                let entry = self.session_entry(session)?;
                // Remove under the slot lock so a concurrent eviction
                // scan cannot spill the session back into the store
                // mid-removal (same discipline as Close).
                let slot = entry.slot.lock().expect("session mutex poisoned");
                if self
                    .registry
                    .sessions
                    .write()
                    .unwrap()
                    .remove(&session)
                    .is_some()
                {
                    self.registry.note_evicted(session, &entry);
                    let _ = self.store.remove(session);
                    self.metrics.on_session_close();
                    self.registry.record(TimelineEvent::Release { session });
                }
                drop(slot);
                Ok(StreamReply::Released { session })
            }
            StreamVerb::Append { session, ys } => {
                let entry = self.session_entry(session)?;
                // Validate before the durable log so a rejected chunk
                // never becomes a replayable record. Empty chunks are a
                // valid poll of the current filtered state — nothing to
                // validate or log. Kalman chunks can only be judged
                // against the session's buffered torn-row tail, so their
                // validation runs below, once the session is resident.
                if !ys.is_empty() {
                    if let ModelHandle::Hmm(hmm) = &entry.model {
                        hmm.check_observations(&ys)?;
                    }
                }
                let reply = (|| -> Result<StreamReply> {
                    let mut slot =
                        entry.slot.lock().expect("session mutex poisoned");
                    self.registry.make_resident(session, &entry, &mut slot)?;
                    let SessionSlot::Resident(s) = &mut *slot else {
                        unreachable!("make_resident")
                    };
                    // Kalman validation — resident (the buffered tail is
                    // part of the judgment) but still ahead of the
                    // durable log, preserving the no-replayable-invalid-
                    // chunk invariant the discrete pre-check provides.
                    if !ys.is_empty()
                        && matches!(entry.model, ModelHandle::Lgssm(_))
                    {
                        s.validate_append(&ys)?;
                    }
                    // Append-ahead: the chunk is durable before the
                    // resident session applies it (a crash between the
                    // two replays it from the log on recovery; a disk
                    // store acks only after a covering group-commit
                    // fsync). Non-durable stores skip the log — their
                    // spill-time snapshot covers everything a
                    // same-process restore needs, and logging every
                    // chunk would duplicate hot sessions' observations
                    // in RAM.
                    if !ys.is_empty() && self.store.durable() {
                        // Attributed as its own stage under the ambient
                        // request span (inert when untraced): the durable
                        // log write, including any group-commit fsync it
                        // waits out (the wait itself is annotated
                        // separately by the store's wait observer).
                        let sp = StageSpan::begin(
                            self.registry.timeline.as_ref(),
                            "store-append",
                        );
                        let logged = self.store.log_append(session, &ys);
                        sp.finish();
                        logged?;
                    }
                    s.push(&ys)?;
                    self.registry.recharge(&entry, s.len());
                    let filtered = match s.filtered() {
                        Ok(f) => f,
                        // A Kalman append may complete no observation
                        // row yet (words buffer until a row closes). The
                        // chunk is already ingested and durably logged,
                        // so the reply must still succeed — an empty
                        // step-0 marginal, not an error the client would
                        // misread as a rejected (hence retryable) append.
                        Err(_)
                            if matches!(
                                entry.model,
                                ModelHandle::Lgssm(_)
                            ) =>
                        {
                            Filtered {
                                probs: Vec::new(),
                                log_likelihood: 0.0,
                                step: 0,
                            }
                        }
                        Err(e) => return Err(e),
                    };
                    let (window, plan_hint) = if entry.meta.lag > 0 {
                        let win = s.smoothed_lag(entry.meta.lag)?;
                        self.metrics.on_suffix_width(win.rescan_width);
                        let hint = self.router.window_hint(
                            self.manifest.as_deref(),
                            Algo::Smooth,
                            win.rescan_width,
                            entry.model.hmm().num_states(),
                            entry.model.hmm().num_symbols(),
                        );
                        (Some(win), hint)
                    } else {
                        (None, None)
                    };
                    let len = s.len();
                    // Periodic checkpoint + compaction bounds the log
                    // length and the append-replay cost of a future
                    // restore (moot for non-durable stores, which have
                    // no log). With a housekeeper the O(T) snapshot
                    // serde runs off the serve path (one in-flight
                    // request per session); in-band mode compacts here,
                    // best-effort — a failed compaction leaves the
                    // (longer but valid) log in place and retries on a
                    // later append.
                    let since = entry
                        .since_ckpt
                        .fetch_add(ys.len() as u64, Ordering::Relaxed)
                        + ys.len() as u64;
                    if since >= self.registry.checkpoint_every as u64
                        && self.store.durable()
                    {
                        match &self.housekeeper {
                            Some(hk) => {
                                if !entry.ckpt_pending.swap(true, Ordering::Relaxed)
                                {
                                    if hk.submit(HkTask::Compact(session)) {
                                        self.metrics.on_hk_enqueued();
                                    } else {
                                        // Queue full: clear the claim so
                                        // a later append re-requests.
                                        entry
                                            .ckpt_pending
                                            .store(false, Ordering::Relaxed);
                                    }
                                }
                            }
                            None => {
                                if self
                                    .store
                                    .compact(session, &entry.meta, &s.snapshot())
                                    .is_ok()
                                {
                                    entry.since_ckpt.store(0, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                    Ok(StreamReply::Appended {
                        session,
                        len,
                        filtered,
                        window,
                        plan_hint,
                    })
                })();
                self.registry.touch(session, &entry);
                if let Ok(StreamReply::Appended { len, .. }) = &reply {
                    self.metrics.on_append(ys.len(), start.elapsed());
                    self.registry.record(TimelineEvent::Append {
                        session,
                        appended: ys.len(),
                        len: *len,
                    });
                }
                // Success or failure, the verb may have restored the
                // session — re-impose (or request) the watermark either
                // way (the failure-path twin of Close's handling).
                self.kick_housekeeping(Some(session));
                reply
            }
            StreamVerb::Stat { session } => {
                let entry = self.session_entry(session)?;
                let (resident, len) = {
                    let slot = entry.slot.lock().expect("session mutex poisoned");
                    match &*slot {
                        SessionSlot::Resident(s) => (true, s.len()),
                        SessionSlot::Evicted { len } => (false, *len),
                    }
                };
                Ok(StreamReply::Stats {
                    session,
                    len,
                    resident,
                    model: entry.meta.model.clone(),
                    open_sessions: self.open_sessions(),
                    resident_sessions: self.resident_sessions(),
                })
            }
            StreamVerb::Close { session } => {
                let entry = self.session_entry(session)?;
                let mut slot = entry.slot.lock().expect("session mutex poisoned");
                self.registry.make_resident(session, &entry, &mut slot)?;
                let SessionSlot::Resident(s) = &mut *slot else {
                    unreachable!("make_resident")
                };
                // finish() before removal: closing a session with no
                // observations is an error that leaves it open (the
                // client can append and retry), never a silent drop. The
                // failed path still re-imposes the watermark — the
                // attempt may have just restored the session.
                let posterior = match s.finish() {
                    Ok(p) => p,
                    Err(e) => {
                        drop(slot);
                        self.kick_housekeeping(None);
                        return Err(e);
                    }
                };
                // Remove under the slot lock so a concurrent eviction
                // scan cannot spill the session back into the store
                // between finish and removal.
                if self
                    .registry
                    .sessions
                    .write()
                    .unwrap()
                    .remove(&session)
                    .is_some()
                {
                    self.registry.note_evicted(session, &entry);
                    // Best-effort: a failed store removal leaves an
                    // orphan log that a later recovery resurrects as a
                    // never-closed session — consistent, just unclosed.
                    let _ = self.store.remove(session);
                    self.metrics.on_session_close();
                    self.registry.record(TimelineEvent::SessionClose { session });
                }
                Ok(StreamReply::Closed { session, posterior })
            }
        }
    }

    fn session_entry(&self, id: u64) -> Result<Arc<SessionEntry>> {
        self.registry.entry(id)
    }

    /// After-verb housekeeping. In background mode (the default) this
    /// is a gauge check plus, when the watermark is breached, one
    /// non-blocking nudge to the worker — the serve path never
    /// snapshots, serializes or fsyncs here. In in-band mode it
    /// enforces the watermark synchronously, exactly as before the
    /// housekeeping worker existed (`protect` shields the session
    /// serving the current verb).
    fn kick_housekeeping(&self, protect: Option<u64>) {
        match &self.housekeeper {
            Some(hk) => {
                if self.registry.over_watermark() && hk.submit(HkTask::Enforce) {
                    self.metrics.on_hk_enqueued();
                }
            }
            None => self.registry.enforce_watermark(protect),
        }
    }

    /// Wait for the background housekeeping worker to drain everything
    /// queued so far and run a final watermark pass; no-op in in-band
    /// mode. Tests and benchmarks use this as a barrier before
    /// asserting residency gauges.
    pub fn quiesce_housekeeping(&self) {
        if let Some(hk) = &self.housekeeper {
            hk.quiesce();
        }
    }

    /// Re-register every session the store holds — the crash-recovery
    /// path. Call after registering models; sessions bound to models not
    /// (yet) registered stay in the store untouched and are picked up by
    /// a later call. Recovered sessions come back *evicted* (lazily
    /// restored on first touch) from the store's **metadata-only** scan
    /// ([`SessionStore::recover_meta`]): with a disk store, startup
    /// reads frame headers, not log bodies, so recovery cost is
    /// O(#sessions) — not O(stored bytes) — no matter how much has been
    /// logged. Returns the number re-registered; the scan's wall time
    /// lands in the `recovery_scan_us` metric gauge.
    pub fn recover_sessions(&self) -> Result<usize> {
        let t0 = Instant::now();
        let stored = self.store.recover_meta()?;
        let mut n = 0usize;
        for (id, meta, len) in stored {
            // Advance the id allocator past *every* stored id — including
            // sessions skipped below — so a fresh open can never reuse
            // (and overwrite the durable log of) a stored session.
            self.next_session.fetch_max(id, Ordering::Relaxed);
            if self.registry.sessions.read().unwrap().contains_key(&id) {
                continue;
            }
            // Recovered sessions must satisfy the same serve-cost guards
            // opens do (appends run O(lag + block) on the serve loop): a
            // restart under tighter limits — or a tampered log — must
            // not smuggle an oversized session past them. Skipped
            // sessions stay in the store; raising the limits and
            // re-running recovery picks them up.
            let max_block =
                self.max_stream_lag.max(crate::engine::DEFAULT_SESSION_BLOCK);
            if meta.lag > self.max_stream_lag
                || meta.options.block.is_some_and(|b| b > max_block)
            {
                continue;
            }
            // Bind to the registry the session's kind names, and refuse
            // to bind stored scan state to a *different* model
            // re-registered under the same name: resume trusts the
            // snapshot's summaries, and mixing them with elements
            // rebuilt from other parameters would silently corrupt
            // results. The session stays in the store for an operator
            // who re-registers the original model.
            let handle = if meta.options.kind == SessionKind::Kalman {
                let Ok(m) = self.lgssm_entry(&meta.model) else { continue };
                if meta.fingerprint.is_some_and(|fp| fp != lgssm_fingerprint(&m))
                {
                    continue;
                }
                ModelHandle::Lgssm(m)
            } else {
                let Ok(model) = self.entry(&meta.model) else { continue };
                if meta
                    .fingerprint
                    .is_some_and(|fp| fp != model_fingerprint(&model.hmm))
                {
                    continue;
                }
                ModelHandle::Hmm(model.hmm)
            };
            let model_name = meta.model.clone();
            self.registry.sessions.write().unwrap().insert(
                id,
                Arc::new(SessionEntry {
                    slot: Mutex::new(SessionSlot::Evicted { len }),
                    model: handle,
                    meta,
                    touch: AtomicU64::new(self.registry.tick()),
                    resident: AtomicBool::new(false),
                    since_ckpt: AtomicU64::new(0),
                    ckpt_pending: AtomicBool::new(false),
                    charged: AtomicUsize::new(0),
                }),
            );
            self.registry.record(TimelineEvent::Recover {
                session: id,
                model: model_name,
                len,
            });
            n += 1;
        }
        self.metrics.on_recovery_scan(t0.elapsed());
        self.metrics.on_recovered(n);
        Ok(n)
    }

    /// Number of currently open streaming sessions (any residency).
    pub fn open_sessions(&self) -> usize {
        self.registry.sessions.read().unwrap().len()
    }

    /// Number of sessions whose element chains are resident in RAM
    /// (bounded by the configured watermark once housekeeping has
    /// caught up — `quiesce_housekeeping` is the barrier).
    pub fn resident_sessions(&self) -> usize {
        self.registry.resident.load(Ordering::Relaxed)
    }

    /// Estimated resident element-chain bytes across all resident
    /// sessions (each weighted T·D²·8) — the gauge the byte-budget
    /// watermark bounds.
    pub fn resident_bytes(&self) -> usize {
        self.registry.resident_bytes.load(Ordering::Relaxed)
    }

    /// The session store behind eviction and recovery (observability).
    pub fn session_store(&self) -> &dyn SessionStore {
        &*self.store
    }

    fn execute(&self, req: &DecodeRequest) -> Result<(DecodeResult, String)> {
        // Fetch the model/engine pair once, atomically, so a concurrent
        // re-registration cannot switch models between plan and run.
        let entry = self.entry(&req.model)?;
        let hmm = entry.hmm;
        hmm.check_observations(&req.ys)?;
        let plan = self.router.plan(
            self.manifest.as_deref(),
            req,
            hmm.num_states(),
            hmm.num_symbols(),
        )?;
        let tag = plan.describe(req.ys.len());
        let result = match &plan {
            ExecutionPlan::Native => {
                let mut engine = entry.engine.lock().expect("engine mutex poisoned");
                decode_result_from(engine.run(req.algo.parallel(), &req.ys)?)?
            }
            ExecutionPlan::PjrtCore { artifact, capacity } => {
                self.run_pjrt_core(&hmm, req, artifact, *capacity)?
            }
            ExecutionPlan::Sharded {
                fold_first,
                fold_mid,
                finalize_first,
                finalize_mid,
                block_len,
                num_blocks,
            } => {
                self.metrics.on_sharded_blocks(*num_blocks);
                let arts = ShardedArtifacts {
                    fold_first: fold_first.clone(),
                    fold_mid: fold_mid.clone(),
                    finalize_first: finalize_first.clone(),
                    finalize_mid: finalize_mid.clone(),
                    block_len: *block_len,
                };
                let pool = self
                    .pool
                    .as_ref()
                    .ok_or_else(|| Error::coordinator("no xla pool"))?;
                match req.algo {
                    Algo::Map => {
                        let (est, _) =
                            sharder::mp_sharded(&**pool, &arts, &hmm, &req.ys)?;
                        DecodeResult::Map(est)
                    }
                    Algo::Smooth | Algo::BayesSmooth => {
                        let (post, _) =
                            sharder::sp_sharded(&**pool, &arts, &hmm, &req.ys)?;
                        DecodeResult::Posterior(post)
                    }
                }
            }
        };
        Ok((result, tag))
    }

    /// PJRT-core plan: dispatch through the engine's XLA backend, which
    /// owns the marshal/decode contract with the compiled artifacts.
    fn run_pjrt_core(
        &self,
        hmm: &Hmm,
        req: &DecodeRequest,
        artifact: &str,
        capacity: usize,
    ) -> Result<DecodeResult> {
        let xla = self
            .xla
            .as_ref()
            .ok_or_else(|| Error::coordinator("no xla backend"))?;
        decode_result_from(xla.run_artifact(
            hmm,
            req.algo.parallel(),
            &req.ys,
            artifact,
            capacity,
        )?)
    }

    /// Spawn the serve loop on its own thread; returns a submit handle.
    pub fn serve(self: Arc<Self>) -> ServerHandle {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let coord = Arc::clone(&self);
        let join = thread::Builder::new()
            .name("hmm-scan-server".into())
            .spawn(move || {
                let mut batcher: Batcher<Envelope> =
                    Batcher::new(coord.batcher_config);
                loop {
                    // Poll with a timeout bounded by the earliest batch
                    // deadline (backpressure: queue depth is bounded by
                    // the channel + batcher occupancy).
                    let timeout = batcher
                        .next_deadline()
                        .map(|d| d.saturating_duration_since(Instant::now()))
                        .unwrap_or(Duration::from_millis(50));
                    match rx.recv_timeout(timeout) {
                        Ok(ServerMsg::Request(req, reply)) => {
                            let key = match coord.plan_for(&req) {
                                Ok(plan) => plan_key(&plan),
                                Err(e) => {
                                    coord.metrics.on_failure();
                                    let _ = reply.send(Err(e));
                                    continue;
                                }
                            };
                            if let Some(batch) =
                                batcher.push(&key, Envelope { req, reply }, Instant::now())
                            {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                        }
                        Ok(ServerMsg::Stream(req, reply)) => {
                            // Streaming verbs bypass the batcher: an
                            // append is latency-sensitive and already
                            // O(k) — coalescing buys nothing.
                            let _ = reply.send(coord.stream(req));
                        }
                        Ok(ServerMsg::Shutdown) => {
                            for batch in batcher.flush_all() {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            for batch in batcher.flush_due(Instant::now()) {
                                coord.metrics.on_batch(batch.items.len());
                                for env in batch.items {
                                    let resp = coord.decode(env.req);
                                    let _ = env.reply.send(resp);
                                }
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
            })
            .expect("spawn server");
        ServerHandle { tx, join: Some(join) }
    }
}

/// Engine output → decode payload (training results are not servable).
fn decode_result_from(out: EngineOutput) -> Result<DecodeResult> {
    match out {
        EngineOutput::Posterior(p) => Ok(DecodeResult::Posterior(p)),
        EngineOutput::Map(m) => Ok(DecodeResult::Map(m)),
        EngineOutput::Training(_) => {
            Err(Error::coordinator("training output cannot be served"))
        }
    }
}

fn plan_key(plan: &ExecutionPlan) -> String {
    match plan {
        ExecutionPlan::PjrtCore { artifact, .. } => format!("pjrt:{artifact}"),
        ExecutionPlan::Sharded { fold_mid, .. } => format!("sharded:{fold_mid}"),
        ExecutionPlan::Native => "native".to_string(),
    }
}

struct Envelope {
    req: DecodeRequest,
    reply: mpsc::Sender<Result<DecodeResponse>>,
}

enum ServerMsg {
    Request(DecodeRequest, mpsc::Sender<Result<DecodeResponse>>),
    Stream(StreamRequest, mpsc::Sender<Result<StreamResponse>>),
    Shutdown,
}

/// Handle to a running serve loop.
pub struct ServerHandle {
    tx: mpsc::Sender<ServerMsg>,
    join: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: DecodeRequest) -> mpsc::Receiver<Result<DecodeResponse>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Request(req, reply));
        rx
    }

    /// Submit a streaming verb (open / append / close); served ahead of
    /// any batching deadline.
    pub fn submit_stream(
        &self,
        req: StreamRequest,
    ) -> mpsc::Receiver<Result<StreamResponse>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Stream(req, reply));
        rx
    }

    /// Drain and stop the serve loop.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ExecMode;
    use crate::hmm::{gilbert_elliott, sample, GeParams};
    use crate::rng::Xoshiro256StarStar;

    fn native_coord() -> Coordinator {
        let c = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        c
    }

    #[test]
    fn native_decode_smoke() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(51);
        let tr = sample(&hmm, 200, &mut rng);
        let resp = c
            .decode(DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.plan, "native");
        let post = resp.result.as_posterior().unwrap();
        assert_eq!(post.len(), 200);
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        assert!((post.log_likelihood() - native.log_likelihood()).abs() < 1e-9);

        let resp = c
            .decode(DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Map))
            .unwrap();
        let est = resp.result.as_map().unwrap();
        assert_eq!(est.path.len(), 200);
    }

    #[test]
    fn unknown_model_and_bad_obs() {
        let c = native_coord();
        assert!(c.decode(DecodeRequest::new(1, "none", vec![0], Algo::Map)).is_err());
        assert!(c.decode(DecodeRequest::new(1, "ge", vec![9], Algo::Map)).is_err());
        assert!(c.decode(DecodeRequest::new(1, "ge", vec![], Algo::Map)).is_err());
        assert_eq!(c.metrics().snapshot().failed, 3);
    }

    #[test]
    fn native_decode_dispatches_through_engine() {
        // Repeated decodes reuse the per-model engine workspace and must
        // stay bit-identical — and match a standalone Engine exactly.
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(56);
        let tr = sample(&hmm, 300, &mut rng);
        let a = c
            .decode(DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        let b = c
            .decode(DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Smooth))
            .unwrap();
        assert_eq!(
            a.result.as_posterior().unwrap(),
            b.result.as_posterior().unwrap()
        );
        let mut engine = crate::engine::Engine::builder(hmm)
            .scan_options(ScanOptions::default())
            .build();
        let direct = engine
            .run(crate::engine::Algorithm::SpPar, &tr.observations)
            .unwrap()
            .into_posterior()
            .unwrap();
        assert_eq!(a.result.as_posterior().unwrap(), &direct);
    }

    #[test]
    fn decode_many_preserves_order() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(52);
        let reqs: Vec<DecodeRequest> = (0..10)
            .map(|i| {
                let tr = sample(&hmm, 50 + (i as usize % 3) * 10, &mut rng);
                DecodeRequest::new(i, "ge", tr.observations, Algo::Smooth)
            })
            .collect();
        let out = c.decode_many(reqs);
        assert_eq!(out.len(), 10);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().id, i as u64);
        }
    }

    #[test]
    fn streaming_open_append_close_round_trip() {
        let c = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(57);
        let tr = sample(&hmm, 300, &mut rng);
        let ys = &tr.observations;

        let resp = c.stream(StreamRequest::open(1, "ge", 16)).unwrap();
        let StreamReply::Opened { session } = resp.reply else {
            panic!("expected Opened, got {:?}", resp.reply)
        };
        assert_eq!(c.open_sessions(), 1);

        let mut pushed = 0usize;
        for (i, chunk) in ys.chunks(100).enumerate() {
            let resp = c
                .stream(StreamRequest::append(10 + i as u64, session, chunk.to_vec()))
                .unwrap();
            pushed += chunk.len();
            let StreamReply::Appended { len, filtered, window, .. } = resp.reply
            else {
                panic!("expected Appended")
            };
            assert_eq!(len, pushed);
            assert_eq!(filtered.step, pushed);
            assert_eq!(filtered.probs.len(), 4);
            let win = window.expect("lag > 0 returns a window");
            assert_eq!(win.posterior.len(), 16.min(pushed));
            // Window loglik is the running full-prefix likelihood.
            let want = crate::inference::sp_seq(&hmm, &ys[..pushed]).unwrap();
            assert!(
                (win.posterior.log_likelihood() - want.log_likelihood()).abs()
                    < 1e-9 * (1.0 + want.log_likelihood().abs())
            );
        }

        let resp = c.stream(StreamRequest::close(99, session)).unwrap();
        let StreamReply::Closed { posterior, .. } = resp.reply else {
            panic!("expected Closed")
        };
        assert_eq!(c.open_sessions(), 0);
        assert_eq!(posterior.len(), 300);
        let want = crate::inference::sp_seq(&hmm, ys).unwrap();
        assert!(
            (posterior.log_likelihood() - want.log_likelihood()).abs()
                < 1e-9 * (1.0 + want.log_likelihood().abs())
        );
        for k in 0..300 {
            for s in 0..4 {
                assert!((posterior.gamma(k)[s] - want.gamma(k)[s]).abs() < 1e-9);
            }
        }

        let snap = c.metrics().snapshot();
        assert_eq!(snap.sessions_opened, 1);
        assert_eq!(snap.sessions_closed, 1);
        assert_eq!(snap.appends, 3);
        assert_eq!(snap.appended_obs, 300);
        assert!(!snap.suffix_width_hist.is_empty());

        // The closed session is gone; unknown ids and bad verbs fail.
        assert!(c.stream(StreamRequest::append(1, session, vec![0])).is_err());
        assert!(c.stream(StreamRequest::close(1, session)).is_err());
        assert!(c.stream(StreamRequest::open(1, "nope", 0)).is_err());
        let resp = c.stream(StreamRequest::open(2, "ge", 0)).unwrap();
        let StreamReply::Opened { session } = resp.reply else { panic!() };
        // Out-of-range symbol: the append fails, the session survives.
        assert!(c.stream(StreamRequest::append(3, session, vec![9])).is_err());
        let resp = c.stream(StreamRequest::append(4, session, vec![0, 1])).unwrap();
        let StreamReply::Appended { window, .. } = resp.reply else { panic!() };
        assert!(window.is_none(), "lag = 0 sessions are filtering-only");
        // An empty chunk is a valid poll: current filtered state, no new
        // observations, nothing logged.
        let resp = c.stream(StreamRequest::append(5, session, vec![])).unwrap();
        let StreamReply::Appended { len, filtered, .. } = resp.reply else {
            panic!()
        };
        assert_eq!(len, 2);
        assert_eq!(filtered.step, 2);

        // A lag beyond the configured cap is rejected at open, and so is
        // an oversized client-chosen checkpoint block (same O(lag + B)
        // append-cost guarantee).
        assert!(c.stream(StreamRequest::open(5, "ge", 1_000_000)).is_err());
        let big_block = StreamRequest {
            id: 5,
            verb: StreamVerb::Open {
                model: "ge".into(),
                options: crate::engine::SessionOptions {
                    block: Some(1 << 30),
                    ..Default::default()
                },
                lag: 8,
            },
        };
        assert!(c.stream(big_block).is_err());

        // Closing a session with no observations errors but leaves it
        // open — the client can append and retry.
        let resp = c.stream(StreamRequest::open(6, "ge", 0)).unwrap();
        let StreamReply::Opened { session: empty } = resp.reply else { panic!() };
        let before = c.open_sessions();
        assert!(c.stream(StreamRequest::close(7, empty)).is_err());
        assert_eq!(c.open_sessions(), before, "failed close must not drop");
        c.stream(StreamRequest::append(8, empty, vec![1, 0])).unwrap();
        assert!(c.stream(StreamRequest::close(9, empty)).is_ok());
        assert_eq!(c.open_sessions(), before - 1);
    }

    /// The eviction acceptance bar: a coordinator with a resident
    /// watermark of K = 4 sustains 20 (> 4K) concurrently open sessions;
    /// appends to evicted sessions restore transparently and every
    /// filtering/closing result is bit-identical to a never-evicted
    /// control coordinator fed the same splits.
    #[test]
    fn watermark_eviction_transparent_restore_bit_identical() {
        let evicting = Coordinator::new(CoordinatorConfig {
            resident_watermark: 4,
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        evicting.register_model("ge", gilbert_elliott(GeParams::default()));
        let control = native_coord(); // default watermark: never evicts
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(0x5711);

        let n = 20usize;
        let mut ids = Vec::new();
        for i in 0..n {
            let a = evicting.stream(StreamRequest::open(i as u64, "ge", 0)).unwrap();
            let b = control.stream(StreamRequest::open(i as u64, "ge", 0)).unwrap();
            let StreamReply::Opened { session: sa } = a.reply else { panic!() };
            let StreamReply::Opened { session: sb } = b.reply else { panic!() };
            ids.push((sa, sb));
        }
        assert_eq!(evicting.open_sessions(), n);
        // Eviction runs on the housekeeping worker by default: quiesce
        // is the barrier that makes the watermark observable.
        evicting.quiesce_housekeeping();
        assert!(evicting.resident_sessions() <= 4);

        // Round-robin appends: every session is evicted and restored
        // repeatedly as its turn comes back around.
        for round in 0..3usize {
            for (i, &(sa, sb)) in ids.iter().enumerate() {
                let t = 20 + (i + round) % 13;
                let chunk = sample(&hmm, t, &mut rng).observations;
                let ra = evicting
                    .stream(StreamRequest::append(1, sa, chunk.clone()))
                    .unwrap();
                let rb =
                    control.stream(StreamRequest::append(1, sb, chunk)).unwrap();
                let StreamReply::Appended { len: la, filtered: fa, .. } = ra.reply
                else {
                    panic!()
                };
                let StreamReply::Appended { len: lb, filtered: fb, .. } = rb.reply
                else {
                    panic!()
                };
                assert_eq!(la, lb);
                assert_eq!(fa, fb, "filtered diverged (session {i} round {round})");
                evicting.quiesce_housekeeping();
                assert!(
                    evicting.resident_sessions() <= 4,
                    "watermark breached at session {i} round {round}"
                );
            }
        }
        let snap = evicting.metrics().snapshot();
        assert!(snap.spills > 0, "eviction never engaged");
        assert!(snap.restores > 0, "no transparent restore happened");

        // Stat reports residency cheaply (no restore is triggered).
        let restores_before = snap.restores;
        let &(sa, _) = ids.first().unwrap();
        let resp = evicting.stream(StreamRequest::stat(99, sa)).unwrap();
        let StreamReply::Stats {
            len, open_sessions, resident_sessions, model, ..
        } = resp.reply
        else {
            panic!("expected Stats")
        };
        assert_eq!(model, "ge");
        assert_eq!(open_sessions, n);
        assert!(resident_sessions <= 4);
        assert!(len > 0);
        assert_eq!(
            evicting.metrics().snapshot().restores,
            restores_before,
            "Stat must not restore"
        );
        assert!(evicting.stream(StreamRequest::stat(1, 999_999)).is_err());

        // Closing restores evicted sessions too; posteriors are bitwise
        // the never-evicted control's.
        for &(sa, sb) in &ids {
            let ra = evicting.stream(StreamRequest::close(2, sa)).unwrap();
            let rb = control.stream(StreamRequest::close(2, sb)).unwrap();
            let StreamReply::Closed { posterior: pa, .. } = ra.reply else {
                panic!()
            };
            let StreamReply::Closed { posterior: pb, .. } = rb.reply else {
                panic!()
            };
            assert_eq!(pa, pb, "posterior diverged from never-evicted control");
        }
        assert_eq!(evicting.open_sessions(), 0);
        assert_eq!(evicting.resident_sessions(), 0);
    }

    /// The DoS backstop is independent of the watermark: opens beyond
    /// `max_open_sessions` get a typed rejection even though eviction
    /// would have kept them resident-legal.
    #[test]
    fn open_session_backstop_is_enforced() {
        let c = Coordinator::new(CoordinatorConfig {
            resident_watermark: 1,
            max_open_sessions: 2,
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        let a = c.stream(StreamRequest::open(1, "ge", 0)).unwrap();
        c.stream(StreamRequest::open(2, "ge", 0)).unwrap();
        assert!(c.stream(StreamRequest::open(3, "ge", 0)).is_err());
        // Closing one frees a slot.
        let StreamReply::Opened { session } = a.reply else { panic!() };
        c.stream(StreamRequest::append(4, session, vec![0, 1])).unwrap();
        c.stream(StreamRequest::close(5, session)).unwrap();
        assert!(c.stream(StreamRequest::open(6, "ge", 0)).is_ok());
    }

    /// A close that restores an evicted session and then fails (empty
    /// session) must not leave residency above the watermark.
    #[test]
    fn failed_close_reimposes_the_watermark() {
        let c = Coordinator::new(CoordinatorConfig {
            resident_watermark: 1,
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        let StreamReply::Opened { session: s1 } =
            c.stream(StreamRequest::open(1, "ge", 0)).unwrap().reply
        else {
            panic!()
        };
        let StreamReply::Opened { session: s2 } =
            c.stream(StreamRequest::open(2, "ge", 0)).unwrap().reply
        else {
            panic!()
        };
        c.quiesce_housekeeping();
        assert_eq!(c.resident_sessions(), 1, "second open must evict the first");

        // Closing the evicted, still-empty s1 restores it and fails —
        // the session survives and residency returns under the mark.
        assert!(c.stream(StreamRequest::close(3, s1)).is_err());
        c.quiesce_housekeeping();
        assert!(c.resident_sessions() <= 1, "failed close breached watermark");

        // Both sessions remain fully usable afterwards.
        c.stream(StreamRequest::append(4, s1, vec![0, 1])).unwrap();
        c.stream(StreamRequest::append(5, s2, vec![1, 0])).unwrap();
        assert!(c.stream(StreamRequest::close(6, s1)).is_ok());
        assert!(c.stream(StreamRequest::close(7, s2)).is_ok());
        assert_eq!(c.open_sessions(), 0);
    }

    /// `housekeeping: false` preserves the pre-worker semantics: every
    /// verb re-imposes the watermark before returning, no barrier
    /// needed.
    #[test]
    fn in_band_mode_enforces_watermark_synchronously() {
        let c = Coordinator::new(CoordinatorConfig {
            resident_watermark: 1,
            housekeeping: false,
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        for i in 0..3u64 {
            c.stream(StreamRequest::open(i, "ge", 0)).unwrap();
        }
        // No quiesce: the serve path itself spilled the victims.
        assert_eq!(c.resident_sessions(), 1);
        assert!(c.metrics().snapshot().spills >= 2);
        assert_eq!(c.metrics().snapshot().hk_enqueued, 0, "no worker in-band");
    }

    /// The byte-budget watermark weighs residency by T·D²·8 bytes: one
    /// fat session breaches a budget that many small ones fit under,
    /// and eviction sheds the cold tail — never the lone survivor.
    #[test]
    fn byte_budget_watermark_spills_by_weight() {
        let hmm = gilbert_elliott(GeParams::default());
        let budget = 600 * 4 * 4 * 8; // ≈ 600 resident observations at D = 4
        let c = Coordinator::new(CoordinatorConfig {
            resident_watermark: 1024, // the count bound never binds here
            resident_bytes_watermark: budget,
            housekeeping: false, // in-band: deterministic gauges
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        c.register_model("ge", hmm.clone());
        let mut rng = Xoshiro256StarStar::seed_from_u64(0xB17E);

        // Eight small sessions fit comfortably under the byte budget.
        for i in 0..8u64 {
            let StreamReply::Opened { session } =
                c.stream(StreamRequest::open(i, "ge", 0)).unwrap().reply
            else {
                panic!()
            };
            let chunk = sample(&hmm, 20, &mut rng).observations;
            c.stream(StreamRequest::append(1, session, chunk)).unwrap();
        }
        assert_eq!(c.resident_sessions(), 8, "small sessions must not spill");
        assert!(c.resident_bytes() <= budget);

        // One fat session blows the budget: cold sessions spill even
        // though the *count* watermark is nowhere near breached.
        let StreamReply::Opened { session: fat } =
            c.stream(StreamRequest::open(99, "ge", 0)).unwrap().reply
        else {
            panic!()
        };
        let chunk = sample(&hmm, 700, &mut rng).observations;
        c.stream(StreamRequest::append(2, fat, chunk)).unwrap();
        assert!(c.metrics().snapshot().spills > 0, "byte budget never engaged");
        assert!(c.resident_sessions() < 9);
        // The freshly-touched fat session itself survives: eviction
        // drains the cold tail first and never spills the last resident.
        let StreamReply::Stats { resident, .. } =
            c.stream(StreamRequest::stat(3, fat)).unwrap().reply
        else {
            panic!()
        };
        assert!(resident, "the hot fat session must not thrash");
    }

    /// The housekeeping concurrency bar: appends race the background
    /// worker's spills and compactions of the very sessions being
    /// appended (watermark 1, tiny checkpoint interval), and every
    /// close stays bit-identical to a never-evicted control coordinator
    /// fed the same chunks.
    #[test]
    fn concurrent_appends_race_background_housekeeping() {
        let dir = crate::store::testutil::tempdir("coord-hk-race");
        let hmm = gilbert_elliott(GeParams::default());
        let racing = Arc::new(
            Coordinator::new(CoordinatorConfig {
                resident_watermark: 1,
                session_store: Some(dir.clone()),
                checkpoint_every: 16,
                ..CoordinatorConfig::native_only()
            })
            .unwrap(),
        );
        racing.register_model("ge", hmm.clone());
        let control = native_coord(); // default watermark: never evicts

        // Pre-generate per-session chunk schedules so both coordinators
        // see identical observations despite the racing threads.
        let mut rng = Xoshiro256StarStar::seed_from_u64(0xACE5);
        let sessions = 4usize;
        let rounds = 12usize;
        let schedules: Vec<Vec<Vec<u32>>> = (0..sessions)
            .map(|_| {
                (0..rounds)
                    .map(|r| sample(&hmm, 5 + (r * 7) % 23, &mut rng).observations)
                    .collect()
            })
            .collect();

        let ids: Vec<u64> = (0..sessions)
            .map(|i| {
                let r =
                    racing.stream(StreamRequest::open(i as u64, "ge", 0)).unwrap();
                let StreamReply::Opened { session } = r.reply else { panic!() };
                session
            })
            .collect();

        std::thread::scope(|scope| {
            for (i, chunks) in schedules.iter().enumerate() {
                let racing = Arc::clone(&racing);
                let id = ids[i];
                scope.spawn(move || {
                    for chunk in chunks {
                        racing
                            .stream(StreamRequest::append(1, id, chunk.clone()))
                            .unwrap();
                    }
                });
            }
        });
        racing.quiesce_housekeeping();
        let snap = racing.metrics().snapshot();
        assert!(snap.spills > 0, "housekeeping never spilled under the race");
        assert!(
            snap.hk_completed > 0,
            "the background worker never processed a task"
        );

        // Control run: same chunks, sequential, never evicted — closes
        // must agree bit-for-bit.
        for (i, chunks) in schedules.iter().enumerate() {
            let r = control
                .stream(StreamRequest::open(50 + i as u64, "ge", 0))
                .unwrap();
            let StreamReply::Opened { session } = r.reply else { panic!() };
            for chunk in chunks {
                control
                    .stream(StreamRequest::append(2, session, chunk.clone()))
                    .unwrap();
            }
            let want = control.stream(StreamRequest::close(3, session)).unwrap();
            let got = racing.stream(StreamRequest::close(3, ids[i])).unwrap();
            let StreamReply::Closed { posterior: b, .. } = want.reply else {
                panic!()
            };
            let StreamReply::Closed { posterior: a, .. } = got.reply else {
                panic!()
            };
            assert_eq!(a, b, "session {i} diverged under background housekeeping");
        }
        assert_eq!(racing.open_sessions(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Crash recovery end-to-end: a disk-backed coordinator is dropped
    /// without closing anything; a fresh one on the same directory
    /// recovers every session from the append-ahead logs (lazily), and
    /// append → close results are bit-identical to a clean engine run
    /// over the full concatenated observations.
    #[test]
    fn disk_store_crash_recovery_restores_all_sessions() {
        let dir = crate::store::testutil::tempdir("coord-recover");
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(0xD15C);
        let mut expected: BTreeMap<u64, Vec<u32>> = BTreeMap::new();

        let config = || CoordinatorConfig {
            resident_watermark: 2,
            session_store: Some(dir.clone()),
            checkpoint_every: 40,
            ..CoordinatorConfig::native_only()
        };
        {
            let c = Coordinator::new(config()).unwrap();
            c.register_model("ge", hmm.clone());
            assert_eq!(c.session_store().name(), "disk");
            for i in 0..6u64 {
                let resp = c.stream(StreamRequest::open(i, "ge", 0)).unwrap();
                let StreamReply::Opened { session } = resp.reply else { panic!() };
                let mut ys = Vec::new();
                for _ in 0..3 {
                    let t = 15 + (i as usize % 7);
                    let chunk = sample(&hmm, t, &mut rng).observations;
                    c.stream(StreamRequest::append(1, session, chunk.clone()))
                        .unwrap();
                    ys.extend_from_slice(&chunk);
                }
                expected.insert(session, ys);
            }
            c.quiesce_housekeeping();
            assert!(c.resident_sessions() <= 2);
            assert!(c.metrics().snapshot().spills > 0);
            // Crash: drop the coordinator without closing anything.
        }

        // Simulate a torn tail write on one log: recovery must keep
        // every fully-framed record and drop only the torn tail.
        let (&torn_id, _) = expected.iter().next().unwrap();
        let torn_path = dir
            .join(format!("{:02x}", torn_id % 256))
            .join(format!("sess_{torn_id:016x}.log"));
        let mut bytes = std::fs::read(&torn_path).unwrap();
        bytes.extend_from_slice(b"00000000000000ff 00"); // truncated header
        std::fs::write(&torn_path, &bytes).unwrap();

        let c = Coordinator::new(config()).unwrap();
        c.register_model("ge", hmm.clone());
        assert_eq!(c.open_sessions(), 0);
        assert_eq!(c.recover_sessions().unwrap(), 6);
        assert_eq!(c.open_sessions(), 6);
        assert_eq!(c.resident_sessions(), 0, "recovery must be lazy");
        assert_eq!(c.metrics().snapshot().sessions_recovered, 6);
        // Recovery is idempotent.
        assert_eq!(c.recover_sessions().unwrap(), 0);

        for (&id, ys) in &expected {
            // Stat reports the fully-logged length without restoring.
            let resp = c.stream(StreamRequest::stat(1, id)).unwrap();
            let StreamReply::Stats { len, resident, .. } = resp.reply else {
                panic!()
            };
            assert_eq!(len, ys.len(), "session {id} lost logged appends");
            assert!(!resident);

            // Appending restores transparently; close is bit-identical
            // to a fresh engine run over the concatenated observations.
            let extra = sample(&hmm, 9, &mut rng).observations;
            c.stream(StreamRequest::append(2, id, extra.clone())).unwrap();
            let resp = c.stream(StreamRequest::close(3, id)).unwrap();
            let StreamReply::Closed { posterior, .. } = resp.reply else {
                panic!()
            };
            let mut full = ys.clone();
            full.extend_from_slice(&extra);
            let mut twin = crate::engine::Engine::builder(hmm.clone())
                .scan_options(
                    ScanOptions::default()
                        .with_block(crate::engine::DEFAULT_SESSION_BLOCK),
                )
                .build();
            let want = twin
                .run(crate::engine::Algorithm::SpPar, &full)
                .unwrap()
                .into_posterior()
                .unwrap();
            assert_eq!(posterior, want, "session {id} diverged after recovery");
        }
        assert_eq!(c.open_sessions(), 0);
        let snap = c.metrics().snapshot();
        assert_eq!(snap.restores, 6);
        assert_eq!(snap.sessions_closed, 6);
        // Closed sessions are gone from the store too.
        assert!(c.session_store().recover().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// New ids never collide with recovered ones.
    #[test]
    fn recovered_ids_advance_the_allocator() {
        let dir = crate::store::testutil::tempdir("coord-ids");
        let hmm = gilbert_elliott(GeParams::default());
        let config = || CoordinatorConfig {
            session_store: Some(dir.clone()),
            ..CoordinatorConfig::native_only()
        };
        let first_ids: Vec<u64> = {
            let c = Coordinator::new(config()).unwrap();
            c.register_model("ge", hmm.clone());
            (0..3)
                .map(|i| {
                    let r = c.stream(StreamRequest::open(i, "ge", 0)).unwrap();
                    let StreamReply::Opened { session } = r.reply else {
                        panic!()
                    };
                    c.stream(StreamRequest::append(9, session, vec![0, 1]))
                        .unwrap();
                    session
                })
                .collect()
        };
        // A *different* model re-registered under the same name must not
        // adopt the stored sessions (fingerprint mismatch): resume would
        // mix its rebuilt elements with the old model's summaries.
        {
            let c = Coordinator::new(config()).unwrap();
            c.register_model(
                "ge",
                gilbert_elliott(GeParams { q0: 0.011, ..GeParams::default() }),
            );
            assert_eq!(
                c.recover_sessions().unwrap(),
                0,
                "recovery bound sessions to a fingerprint-mismatched model"
            );
            assert_eq!(c.open_sessions(), 0);
        }

        let c = Coordinator::new(config()).unwrap();
        c.register_model("ge", hmm);
        // Even an open served *before* recover_sessions must not reuse a
        // stored id (the store seeds the allocator at construction) —
        // DiskStore::create would otherwise overwrite the crashed
        // session's durable log.
        let r = c.stream(StreamRequest::open(6, "ge", 0)).unwrap();
        let StreamReply::Opened { session: early } = r.reply else { panic!() };
        assert!(
            !first_ids.contains(&early),
            "pre-recovery open {early} collides with a stored session"
        );
        assert_eq!(c.recover_sessions().unwrap(), 3);
        let r = c.stream(StreamRequest::open(7, "ge", 0)).unwrap();
        let StreamReply::Opened { session } = r.reply else { panic!() };
        assert!(
            !first_ids.contains(&session) && session != early,
            "fresh id {session} collides with a recovered session"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The replay acceptance bar: folding the event timeline
    /// reconstructs the live registry view — per-session model, length
    /// and residency plus the open/resident counts — exactly as `Stat`
    /// reports it, across opens, appends, spills, restores, a close and
    /// a crash recovery; an `--until` cut reproduces the intermediate
    /// state at that seq.
    #[test]
    fn timeline_replay_matches_live_registry_state() {
        use crate::obs::{read_events, replay_records};

        let dir = crate::store::testutil::tempdir("coord-timeline");
        let tl_dir = dir.join("timeline");
        let timeline = Timeline::open(&tl_dir).unwrap();
        let hmm = gilbert_elliott(GeParams::default());
        let config = || CoordinatorConfig {
            resident_watermark: 1,
            housekeeping: false, // in-band: deterministic spill order
            session_store: Some(dir.join("store")),
            timeline: Some(Arc::clone(&timeline)),
            ..CoordinatorConfig::native_only()
        };
        let (s1, s2);
        {
            let c = Coordinator::new(config()).unwrap();
            c.register_model("ge", hmm.clone());
            let StreamReply::Opened { session } =
                c.stream(StreamRequest::open(1, "ge", 0)).unwrap().reply
            else {
                panic!()
            };
            s1 = session;
            c.stream(StreamRequest::append(2, s1, vec![0, 1, 1])).unwrap();
            // Watermark 1: opening s2 spills s1 in-band.
            let StreamReply::Opened { session } =
                c.stream(StreamRequest::open(3, "ge", 0)).unwrap().reply
            else {
                panic!()
            };
            s2 = session;
            c.stream(StreamRequest::append(4, s2, vec![1, 0])).unwrap();
            // Appending to the spilled s1 restores it and spills s2.
            c.stream(StreamRequest::append(5, s1, vec![0])).unwrap();
            let snap = c.metrics().snapshot();
            assert_eq!((snap.spills, snap.restores), (2, 1));

            // Live truth at this seq, straight from Stat.
            let StreamReply::Stats {
                len,
                resident,
                model,
                open_sessions,
                resident_sessions,
                ..
            } = c.stream(StreamRequest::stat(6, s1)).unwrap().reply
            else {
                panic!()
            };

            timeline.flush();
            let records = read_events(&tl_dir).unwrap();
            let state = replay_records(&records, None);
            assert_eq!(state.last_seq, timeline.last_seq());
            assert_eq!(state.open_sessions(), open_sessions);
            assert_eq!(state.resident_sessions(), resident_sessions);
            let view = &state.sessions[&s1];
            assert_eq!(
                (view.model.as_str(), view.len, view.resident),
                (model.as_str(), len, resident)
            );
            assert_eq!(
                (state.sessions[&s2].len, state.sessions[&s2].resident),
                (2, false)
            );

            // Cut the replay at the first spill: both sessions open,
            // s1 just evicted at length 3, only s2 resident.
            let cut = records
                .iter()
                .find(|r| matches!(r.event, TimelineEvent::Spill { .. }))
                .unwrap()
                .seq;
            let mid = replay_records(&records, Some(cut));
            assert_eq!(mid.open_sessions(), 2);
            assert_eq!(mid.resident_sessions(), 1);
            assert_eq!(
                (mid.sessions[&s1].len, mid.sessions[&s1].resident),
                (3, false)
            );

            // Close s2 (restores it first), then crash with s1 open.
            c.stream(StreamRequest::close(7, s2)).unwrap();
        }

        let c = Coordinator::new(config()).unwrap();
        c.register_model("ge", hmm);
        assert_eq!(c.recover_sessions().unwrap(), 1);
        let StreamReply::Stats {
            len, resident, open_sessions, resident_sessions, ..
        } = c.stream(StreamRequest::stat(8, s1)).unwrap().reply
        else {
            panic!()
        };

        timeline.flush();
        let records = read_events(&tl_dir).unwrap();
        let state = replay_records(&records, None);
        assert_eq!(state.recovered, 1);
        assert_eq!(state.open_sessions(), open_sessions);
        assert_eq!(state.resident_sessions(), resident_sessions);
        assert_eq!(
            (state.sessions[&s1].len, state.sessions[&s1].resident),
            (len, resident)
        );
        assert!(
            !state.sessions.contains_key(&s2),
            "closed session must replay away"
        );
        assert_eq!(timeline.dropped(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Durable appends executed under an ambient request span emit a
    /// `store-append` span and a `sync-wait` annotation, both parented
    /// to the caller's span — fsync latency is attributed to the
    /// request that paid it, not lost inside the store.
    #[test]
    fn durable_appends_emit_store_spans_under_ambient_trace() {
        use crate::obs::span;
        use crate::obs::{merge_records, read_events, trace_views};

        let dir = crate::store::testutil::tempdir("coord-store-span");
        let tl_dir = dir.join("timeline");
        let timeline = Timeline::open(&tl_dir).unwrap();
        let c = Coordinator::new(CoordinatorConfig {
            session_store: Some(dir.join("store")),
            timeline: Some(Arc::clone(&timeline)),
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        let StreamReply::Opened { session } =
            c.stream(StreamRequest::open(1, "ge", 0)).unwrap().reply
        else {
            panic!()
        };

        // Simulate the serving path: the net server would make the
        // execute span ambient before calling into the coordinator.
        let trace = span::fresh_id();
        let exec = span::fresh_id();
        span::with_span(trace, exec, || {
            c.stream(StreamRequest::append(2, session, vec![0, 1, 1]))
                .unwrap();
        });
        c.stream(StreamRequest::close(3, session)).unwrap();

        timeline.flush();
        let records = read_events(&tl_dir).unwrap();
        let merged = merge_records(&[("coord".to_string(), records)]);
        let views = trace_views(&merged);
        let view = views
            .iter()
            .find(|v| v.trace == trace)
            .expect("traced append produced no trace view");
        assert!(!view.torn, "store spans left the trace torn");
        let stages: Vec<&str> =
            view.spans.iter().map(|s| s.stage.as_str()).collect();
        assert!(stages.contains(&"store-append"), "stages: {stages:?}");
        assert!(stages.contains(&"sync-wait"), "stages: {stages:?}");
        for s in &view.spans {
            assert_eq!(
                s.parent, exec,
                "stage {} must parent the ambient span",
                s.stage
            );
            assert!(s.us.is_some(), "stage {} never closed", s.stage);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    fn open_kalman_req(id: u64, model: &str, lag: usize) -> StreamRequest {
        StreamRequest {
            id,
            verb: StreamVerb::Open {
                model: model.into(),
                options: crate::engine::SessionOptions {
                    kind: SessionKind::Kalman,
                    ..Default::default()
                },
                lag,
            },
        }
    }

    /// Kalman session guards at the coordinator layer: the kind picks
    /// the linear-Gaussian registry, lag is rejected (filtering-only),
    /// invalid rows never reach the durable log, and a torn first
    /// append acks with an empty step-0 marginal instead of an error.
    #[test]
    fn kalman_session_guards_and_torn_appends() {
        use crate::kalman::{obs_to_words, Lgssm};
        let c = native_coord(); // registers the "ge" HMM
        c.register_lgssm("cv", Lgssm::constant_velocity(0.1, 0.8, 0.5));
        assert_eq!(c.lgssm("cv").unwrap().state_dim(), 4);
        assert!(c.lgssm("ge").is_err(), "HMM names are not Lgssm names");

        // Filtering-only: any fixed-lag width is rejected at open.
        assert!(c.stream(open_kalman_req(1, "cv", 8)).is_err());
        // Kind Kalman resolves the model in the Lgssm registry — the
        // "ge" HMM is invisible there.
        assert!(c.stream(open_kalman_req(2, "ge", 0)).is_err());

        let StreamReply::Opened { session } =
            c.stream(open_kalman_req(3, "cv", 0)).unwrap().reply
        else {
            panic!()
        };
        // A non-finite observation row is rejected atomically.
        let nan_row = obs_to_words(&[f64::NAN, 1.0]);
        assert!(c.stream(StreamRequest::append(4, session, nan_row)).is_err());
        let StreamReply::Stats { len, .. } =
            c.stream(StreamRequest::stat(5, session)).unwrap().reply
        else {
            panic!()
        };
        assert_eq!(len, 0, "rejected append must not advance the session");

        // A torn first append (3 of 4 words) is ingested and acked with
        // an empty step-0 marginal — not an error the client would
        // retry (the words are already durably owned by the session).
        let words = obs_to_words(&[1.0, 2.0]);
        let StreamReply::Appended { len, filtered, window, .. } = c
            .stream(StreamRequest::append(6, session, words[..3].to_vec()))
            .unwrap()
            .reply
        else {
            panic!()
        };
        assert_eq!(len, 3);
        assert_eq!(filtered.step, 0);
        assert!(filtered.probs.is_empty());
        assert!(window.is_none());

        // Completing the row yields the real mean ++ covariance payload.
        let StreamReply::Appended { len, filtered, .. } = c
            .stream(StreamRequest::append(7, session, words[3..].to_vec()))
            .unwrap()
            .reply
        else {
            panic!()
        };
        assert_eq!(len, 4);
        assert_eq!(filtered.step, 1);
        assert_eq!(filtered.probs.len(), 4 + 16);

        // Close succeeds once no torn words are pending.
        let StreamReply::Closed { posterior, .. } =
            c.stream(StreamRequest::close(8, session)).unwrap().reply
        else {
            panic!()
        };
        assert_eq!(posterior.len(), 1);
    }

    /// The Kalman-tier acceptance bar: durable Kalman sessions survive
    /// spill → transparent restore → crash recovery → close, with every
    /// reply bit-identical to a never-evicted control coordinator fed
    /// the same word chunks (torn mid-f64 at arbitrary boundaries).
    #[test]
    fn kalman_sessions_survive_eviction_and_crash_recovery() {
        use crate::kalman::{obs_to_words, tests_support::tracking_obs, Lgssm};

        let dir = crate::store::testutil::tempdir("coord-kalman");
        let model = || Lgssm::constant_velocity(0.1, 0.8, 0.5);
        let config = || CoordinatorConfig {
            resident_watermark: 2,
            session_store: Some(dir.clone()),
            checkpoint_every: 64,
            ..CoordinatorConfig::native_only()
        };

        // Word schedules per session, chunked so f64 halves and whole
        // observation rows tear at varying boundaries.
        let sessions = 5usize;
        let schedules: Vec<Vec<Vec<u32>>> = (0..sessions)
            .map(|i| {
                let m = model();
                let obs = tracking_obs(&m, 40 + 11 * i, i as u64);
                let words = obs_to_words(&obs);
                let mut chunks = Vec::new();
                let (mut lo, mut step) = (0usize, 3usize);
                while lo < words.len() {
                    let hi = (lo + step).min(words.len());
                    chunks.push(words[lo..hi].to_vec());
                    lo = hi;
                    step = step % 9 + 3; // cycles 3..=11
                }
                chunks
            })
            .collect();

        let control =
            Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        control.register_lgssm("cv", model());
        let control_ids: Vec<u64> = (0..sessions)
            .map(|i| {
                let r = control.stream(open_kalman_req(i as u64, "cv", 0));
                let StreamReply::Opened { session } = r.unwrap().reply else {
                    panic!()
                };
                session
            })
            .collect();

        let mut expected_len = vec![0usize; sessions];
        let ids: Vec<u64>;
        {
            let c = Coordinator::new(config()).unwrap();
            c.register_lgssm("cv", model());
            ids = (0..sessions)
                .map(|i| {
                    let r = c.stream(open_kalman_req(i as u64, "cv", 0));
                    let StreamReply::Opened { session } = r.unwrap().reply
                    else {
                        panic!()
                    };
                    session
                })
                .collect();
            // Interleave chunk k of every session so the watermark-2
            // coordinator keeps spilling and restoring mid-stream, with
            // torn-row tails crossing the snapshot boundary.
            let rounds = schedules.iter().map(Vec::len).max().unwrap();
            for k in 0..rounds {
                for i in 0..sessions {
                    let Some(chunk) = schedules[i].get(k) else { continue };
                    let ra = c
                        .stream(StreamRequest::append(1, ids[i], chunk.clone()))
                        .unwrap();
                    let rb = control
                        .stream(StreamRequest::append(
                            1,
                            control_ids[i],
                            chunk.clone(),
                        ))
                        .unwrap();
                    expected_len[i] += chunk.len();
                    let StreamReply::Appended {
                        len: la,
                        filtered: fa,
                        window: wa,
                        ..
                    } = ra.reply
                    else {
                        panic!()
                    };
                    let StreamReply::Appended { len: lb, filtered: fb, .. } =
                        rb.reply
                    else {
                        panic!()
                    };
                    assert_eq!(la, expected_len[i]);
                    assert_eq!(la, lb);
                    assert_eq!(
                        fa, fb,
                        "filtered diverged (session {i} chunk {k})"
                    );
                    assert!(wa.is_none(), "kalman sessions never window");
                }
            }
            c.quiesce_housekeeping();
            assert!(c.resident_sessions() <= 2);
            assert!(c.metrics().snapshot().spills > 0, "eviction never ran");
            // Crash: drop the coordinator without closing anything.
        }

        // A *different* Lgssm re-registered under the same name must
        // not adopt the stored sessions (Gaussian fingerprint mismatch).
        {
            let c = Coordinator::new(config()).unwrap();
            c.register_lgssm("cv", Lgssm::constant_velocity(0.1, 0.8, 0.6));
            assert_eq!(c.recover_sessions().unwrap(), 0);
        }

        let c = Coordinator::new(config()).unwrap();
        c.register_lgssm("cv", model());
        assert_eq!(c.recover_sessions().unwrap(), sessions);
        assert_eq!(c.resident_sessions(), 0, "recovery must be lazy");
        for i in 0..sessions {
            // Stat reports the logged word count without restoring.
            let StreamReply::Stats { len, resident, model: name, .. } =
                c.stream(StreamRequest::stat(1, ids[i])).unwrap().reply
            else {
                panic!()
            };
            assert_eq!(len, expected_len[i], "session {i} lost words");
            assert!(!resident);
            assert_eq!(name, "cv");

            // Close restores transparently; the posterior is bitwise
            // the never-evicted control's (which the engine tests pin
            // to the one-shot parallel smoother).
            let ra = c.stream(StreamRequest::close(2, ids[i])).unwrap();
            let rb = control
                .stream(StreamRequest::close(2, control_ids[i]))
                .unwrap();
            let StreamReply::Closed { posterior: pa, .. } = ra.reply else {
                panic!()
            };
            let StreamReply::Closed { posterior: pb, .. } = rb.reply else {
                panic!()
            };
            assert_eq!(pa, pb, "session {i} diverged across spill/recover");
        }
        assert_eq!(c.open_sessions(), 0);
        assert!(c.session_store().recover().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_loop_streams_alongside_decodes() {
        let c = Arc::new(native_coord());
        let handle = Arc::clone(&c).serve();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(58);

        let opened = handle
            .submit_stream(StreamRequest::open(0, "ge", 8))
            .recv()
            .unwrap()
            .unwrap();
        let StreamReply::Opened { session } = opened.reply else { panic!() };

        // Interleave decodes and appends through the same loop.
        let tr = sample(&hmm, 64, &mut rng);
        let decode_rx =
            handle.submit(DecodeRequest::new(7, "ge", tr.observations, Algo::Smooth));
        let append_rx = handle.submit_stream(StreamRequest::append(
            1,
            session,
            sample(&hmm, 50, &mut rng).observations,
        ));
        assert!(append_rx.recv().unwrap().is_ok());
        assert!(decode_rx.recv().unwrap().is_ok());

        let closed = handle
            .submit_stream(StreamRequest::close(2, session))
            .recv()
            .unwrap()
            .unwrap();
        match closed.reply {
            StreamReply::Closed { posterior, .. } => assert_eq!(posterior.len(), 50),
            other => panic!("expected Closed, got {other:?}"),
        }
        handle.shutdown();
        let snap = c.metrics().snapshot();
        assert_eq!(snap.sessions_opened, 1);
        assert_eq!(snap.sessions_closed, 1);
        assert_eq!(snap.completed, 1);
    }

    // ---- PJRT-backed tests (skip when artifacts are absent) ----

    fn pjrt_coord() -> Option<Coordinator> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {dir:?}");
            return None;
        }
        let c = Coordinator::new(CoordinatorConfig {
            artifacts: Some(dir),
            xla_workers: 2,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        Some(c)
    }

    #[test]
    fn pjrt_core_decode_matches_native() {
        let Some(c) = pjrt_coord() else { return };
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(53);
        let tr = sample(&hmm, 100, &mut rng); // pads into T=128 artifact
        let req = DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth)
            .with_mode(ExecMode::Pjrt);
        let resp = c.decode(req).unwrap();
        assert!(resp.plan.starts_with("pjrt:sp_par_T128"), "{}", resp.plan);
        let post = resp.result.as_posterior().unwrap();
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        for k in 0..100 {
            for s in 0..4 {
                assert!((post.gamma(k)[s] - native.gamma(k)[s]).abs() < 1e-4);
            }
        }
        assert!(
            (post.log_likelihood() - native.log_likelihood()).abs()
                < 1e-3 * native.log_likelihood().abs()
        );
    }

    #[test]
    fn sharded_decode_matches_native() {
        let Some(c) = pjrt_coord() else { return };
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(54);
        // Longer than the largest (8192) core artifact → sharded.
        let tr = sample(&hmm, 10_000, &mut rng);
        let req = DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth);
        let plan = c.plan_for(&req).unwrap();
        assert!(matches!(plan, ExecutionPlan::Sharded { .. }), "{plan:?}");
        let resp = c.decode(req).unwrap();
        let post = resp.result.as_posterior().unwrap();
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        let mut max_err = 0.0f64;
        for k in 0..10_000 {
            for s in 0..4 {
                max_err = max_err.max((post.gamma(k)[s] - native.gamma(k)[s]).abs());
            }
        }
        assert!(max_err < 1e-3, "sharded smoother max err {max_err}");
        assert!(c.metrics().snapshot().sharded_blocks > 0);

        // MAP, sharded.
        let req = DecodeRequest::new(2, "ge", tr.observations.clone(), Algo::Map);
        let resp = c.decode(req).unwrap();
        let est = resp.result.as_map().unwrap();
        let native = crate::inference::viterbi(&hmm, &tr.observations).unwrap();
        assert!(
            (est.log_prob - native.log_prob).abs()
                < 1e-3 * native.log_prob.abs(),
            "{} vs {}",
            est.log_prob,
            native.log_prob
        );
    }

    #[test]
    fn serve_loop_round_trip() {
        let c = Arc::new(native_coord());
        let handle = Arc::clone(&c).serve();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(55);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                let tr = sample(&hmm, 64, &mut rng);
                handle.submit(DecodeRequest::new(i, "ge", tr.observations, Algo::Smooth))
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, i as u64);
        }
        handle.shutdown();
        let snap = c.metrics().snapshot();
        assert_eq!(snap.completed, 8);
        assert!(snap.batches >= 1);
    }
}

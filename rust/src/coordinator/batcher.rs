//! Dynamic batcher: coalesce same-artifact requests inside a deadline
//! window.
//!
//! PJRT dispatch has a fixed per-call overhead; grouping requests that
//! target the same compiled artifact lets the worker pool run them
//! back-to-back on one executable handle (and, for sharded plans, lets
//! block folds from different requests interleave on the pool).
//!
//! Invariants (property-tested):
//!   * a batch never mixes artifact keys,
//!   * `max_batch` is never exceeded,
//!   * no request is held past `max_delay` (relative to its enqueue
//!     time) once `flush_due` is called with a current timestamp,
//!   * FIFO order within a key is preserved.
//!
//! The batcher is pure state-machine logic over injected timestamps —
//! no threads, no clocks — so it is exhaustively testable; the server
//! drives it from the queue loop.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Maximum time a request may wait for co-batching.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 16, max_delay: Duration::from_millis(2) }
    }
}

/// A group of work items that share an artifact key.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch<T> {
    /// Artifact key the items share.
    pub key: String,
    /// The batched work items, enqueue order preserved.
    pub items: Vec<T>,
}

struct Pending<T> {
    item: T,
    enqueued: Instant,
}

/// Deadline-window batcher keyed by artifact name.
pub struct Batcher<T> {
    config: BatcherConfig,
    queues: Vec<(String, VecDeque<Pending<T>>)>,
}

impl<T> Batcher<T> {
    /// An empty batcher under `config`'s window/size policy.
    pub fn new(config: BatcherConfig) -> Self {
        Self { config, queues: Vec::new() }
    }

    /// Number of queued items across all keys.
    pub fn depth(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Enqueue an item under `key` at time `now`. Returns a full batch
    /// immediately if the key's queue reached `max_batch`.
    pub fn push(&mut self, key: &str, item: T, now: Instant) -> Option<Batch<T>> {
        let queue = match self.queues.iter_mut().find(|(k, _)| k == key) {
            Some((_, q)) => q,
            None => {
                self.queues.push((key.to_string(), VecDeque::new()));
                &mut self.queues.last_mut().unwrap().1
            }
        };
        queue.push_back(Pending { item, enqueued: now });
        if queue.len() >= self.config.max_batch {
            let items = queue
                .drain(..self.config.max_batch)
                .map(|p| p.item)
                .collect();
            return Some(Batch { key: key.to_string(), items });
        }
        None
    }

    /// Release every batch whose oldest item has waited ≥ `max_delay`.
    pub fn flush_due(&mut self, now: Instant) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        for (key, queue) in &mut self.queues {
            let due = queue
                .front()
                .map(|p| now.duration_since(p.enqueued) >= self.config.max_delay)
                .unwrap_or(false);
            if due {
                let n = queue.len().min(self.config.max_batch);
                let items = queue.drain(..n).map(|p| p.item).collect();
                out.push(Batch { key: key.clone(), items });
            }
        }
        out
    }

    /// Release everything regardless of deadlines (shutdown / sync path).
    pub fn flush_all(&mut self) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        for (key, queue) in &mut self.queues {
            while !queue.is_empty() {
                let n = queue.len().min(self.config.max_batch);
                let items = queue.drain(..n).map(|p| p.item).collect();
                out.push(Batch { key: key.clone(), items });
            }
        }
        out
    }

    /// Earliest deadline across queues (for the server's poll timeout).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|(_, q)| q.front().map(|p| p.enqueued + self.config.max_delay))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, ms: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_delay: Duration::from_millis(ms) }
    }

    #[test]
    fn full_batch_released_immediately() {
        let mut b = Batcher::new(cfg(3, 1000));
        let t0 = Instant::now();
        assert!(b.push("a", 1, t0).is_none());
        assert!(b.push("a", 2, t0).is_none());
        let batch = b.push("a", 3, t0).unwrap();
        assert_eq!(batch.key, "a");
        assert_eq!(batch.items, vec![1, 2, 3]);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn keys_never_mix() {
        let mut b = Batcher::new(cfg(2, 1000));
        let t0 = Instant::now();
        b.push("a", 1, t0);
        b.push("b", 2, t0);
        let batch = b.push("a", 3, t0).unwrap();
        assert_eq!(batch.items, vec![1, 3]);
        assert_eq!(b.depth(), 1); // "b" still queued
    }

    #[test]
    fn deadline_flush() {
        let mut b = Batcher::new(cfg(10, 5));
        let t0 = Instant::now();
        b.push("a", 1, t0);
        b.push("a", 2, t0 + Duration::from_millis(1));
        assert!(b.flush_due(t0 + Duration::from_millis(4)).is_empty());
        let out = b.flush_due(t0 + Duration::from_millis(5));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].items, vec![1, 2]);
    }

    #[test]
    fn flush_all_splits_by_max_batch() {
        let mut b = Batcher::new(cfg(2, 1000));
        let t0 = Instant::now();
        for i in 0..5 {
            assert!(b.push("a", i, t0).is_none() || i % 2 == 1);
        }
        let out = b.flush_all();
        // 5 items pushed; push() emitted full batches at items 2 and 4,
        // so flush_all returns the remaining 1.
        let total: usize = out.iter().map(|x| x.items.len()).sum();
        assert_eq!(total, 1);
        assert!(out.iter().all(|x| x.items.len() <= 2));
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn next_deadline_is_earliest() {
        let mut b = Batcher::new(cfg(10, 7));
        let t0 = Instant::now();
        b.push("a", 1, t0 + Duration::from_millis(3));
        b.push("b", 2, t0);
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(7));
    }

    #[test]
    fn invariants_property() {
        let mut runner = crate::proptestx::Runner::new("batcher-invariants");
        runner.run(50, |r| {
            let max_batch = 1 + r.below(8) as usize;
            let mut b = Batcher::new(cfg(max_batch, 10));
            let t0 = Instant::now();
            let keys = ["k0", "k1", "k2"];
            let mut emitted: Vec<Batch<u64>> = Vec::new();
            let mut pushed_per_key = [0u64; 3];
            let n = r.below(200) as usize;
            for i in 0..n {
                let ki = r.below(3) as usize;
                let now = t0 + Duration::from_millis(i as u64);
                if let Some(batch) = b.push(keys[ki], pushed_per_key[ki], now) {
                    emitted.push(batch);
                }
                pushed_per_key[ki] += 1;
                if r.below(10) == 0 {
                    emitted.extend(b.flush_due(t0 + Duration::from_millis(i as u64)));
                }
            }
            emitted.extend(b.flush_all());
            assert_eq!(b.depth(), 0);
            // max batch respected; FIFO within key; nothing lost.
            let mut seen = [0u64; 3];
            let mut counts = [0u64; 3];
            for batch in &emitted {
                assert!(batch.items.len() <= max_batch);
                let ki = keys.iter().position(|k| *k == batch.key).unwrap();
                for &item in &batch.items {
                    assert_eq!(item, seen[ki], "FIFO violated for {}", batch.key);
                    seen[ki] += 1;
                    counts[ki] += 1;
                }
            }
            assert_eq!(counts, pushed_per_key);
        });
    }
}

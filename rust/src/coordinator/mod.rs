//! The coordinator — the L3 serving layer.
//!
//! Shapes the paper's algorithms into a deployable inference service:
//!
//! * [`request`] — request/response types (`DecodeRequest` → smoothing
//!   marginals or a MAP path).
//! * [`router`] — picks an execution plan per request: an exact-size or
//!   padded PJRT core artifact, the native library, or a block-wise
//!   **sharded** plan (the paper's §V-B) for sequences longer than any
//!   compiled artifact.
//! * [`batcher`] — dynamic batching: coalesces same-artifact requests
//!   inside a deadline window so PJRT dispatch is amortized.
//! * [`sharder`] — executes sharded plans: per-block fold artifacts on
//!   the worker pool, native associative combine at the leader, per-block
//!   finalize artifacts — the two-level scan, operationalized.
//! * [`metrics`] — queue depth, batch occupancy, latency percentiles,
//!   throughput counters.
//! * [`server`] — the `Coordinator` itself: model registry, worker pool,
//!   synchronous and batched entry points, a per-session registry for
//!   the streaming verbs ([`StreamRequest`]: open → append* → stat /
//!   close, backed by `engine::Session` and the durable
//!   `store::SessionStore` — watermark-driven eviction, transparent
//!   restore, crash recovery), and a channel-fed serve loop.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod sharder;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot, WireVerbStats, WorkerLinkStats};
pub use request::{
    Algo, DecodeRequest, DecodeResponse, DecodeResult, ExecMode, StreamReply,
    StreamRequest, StreamResponse, StreamVerb,
};
pub use router::{ExecutionPlan, Router, RouterConfig};
pub use server::{Coordinator, CoordinatorConfig};

//! Temporal sharder — the §V-B block-wise scan executed over PJRT
//! artifacts.
//!
//! Protocol (mirrors `blockwise::` natively and is tested against it):
//!
//! 1. **Fold** — every block of L observations is folded to its summary
//!    element a_{s:e} by one `*_block_fold_{first,mid}` artifact call;
//!    calls are independent and run concurrently on the XLA worker pool.
//! 2. **Combine** — the leader prefix/suffix-combines the B ≈ T/L
//!    summaries natively with ⊗ / ∨ (O(B·D³), tiny).
//! 3. **Finalize** — every block is completed by one
//!    `*_block_finalize_{first,mid}` call receiving its incoming forward
//!    prefix and backward suffix; outputs are concatenated.
//!
//! This is how a *fixed* set of compiled artifact sizes serves unbounded
//! sequence lengths.

use crate::blockwise::BlockPlan;
use crate::elements::{mp_terminal, sp_terminal, MpElement, MpOp, SpElement, SpOp};
use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::inference::{MapEstimate, Posterior};
use crate::linalg::{argmax, normalize_sum, Mat};
use crate::runtime::Value;
use crate::scan::AssocOp;

// Execution abstraction + input marshalling live in the runtime layer
// (shared with `engine::XlaBackend`); re-exported here so existing
// `sharder::{ArtifactExec, marshal_block}` paths keep working.
pub use crate::runtime::{marshal_block, ArtifactExec};

/// Sharded-plan parameters resolved by the router.
#[derive(Debug, Clone)]
pub struct ShardedArtifacts {
    /// Fold artifact for the first block (seeds the prior).
    pub fold_first: String,
    /// Fold artifact for every interior block.
    pub fold_mid: String,
    /// Finalize artifact for the first block.
    pub finalize_first: String,
    /// Finalize artifact for every interior block.
    pub finalize_mid: String,
    /// Observations per block the artifacts were compiled for.
    pub block_len: usize,
}

fn mat_from_f32(data: &[f32], d: usize) -> Mat {
    Mat::from_vec(d, d, data.iter().map(|&v| v as f64).collect())
}

fn mat_to_f32(m: &Mat) -> Value {
    Value::F32(
        m.data().iter().map(|&v| v as f32).collect(),
        vec![m.rows(), m.cols()],
    )
}

/// Run the sharded sum-product smoother. Returns the posterior plus the
/// number of artifact calls made (for metrics).
pub fn sp_sharded(
    exec: &dyn ArtifactExec,
    arts: &ShardedArtifacts,
    hmm: &Hmm,
    ys: &[u32],
) -> Result<(Posterior, usize)> {
    let d = hmm.num_states();
    let t = ys.len();
    let plan = BlockPlan::new(t, arts.block_len);
    let nb = plan.num_blocks();
    let op = SpOp { d };

    // Phase 1: fold every block (concurrently).
    let jobs: Vec<(String, Vec<Value>)> = (0..nb)
        .map(|b| {
            let (s, e) = plan.range(b);
            let name = if b == 0 { &arts.fold_first } else { &arts.fold_mid };
            (name.clone(), marshal_block(hmm, &ys[s..e], arts.block_len))
        })
        .collect();
    let folds: Vec<SpElement> = exec
        .run_many(jobs)
        .into_iter()
        .map(|r| {
            let out = r?;
            let mat = mat_from_f32(out[0].as_f32()?, d);
            let log = out[1].scalar()?;
            Ok(SpElement { mat, log_scale: log })
        })
        .collect::<Result<_>>()?;

    // Phase 2: leader combine — exclusive prefixes and suffixes.
    let mut prefixes = Vec::with_capacity(nb);
    let mut acc = op.identity();
    for f in &folds {
        prefixes.push(acc.clone());
        acc = op.combine(&acc, f);
    }
    let total = acc; // a_{0:T}
    let loglik = total.log_scale
        + total.mat.row(0).iter().sum::<f64>().max(f64::MIN_POSITIVE).ln();
    let mut suffixes = vec![op.identity(); nb];
    let mut acc = sp_terminal(d);
    for b in (0..nb).rev() {
        suffixes[b] = acc.clone();
        acc = op.combine(&folds[b], &acc);
    }

    // Phase 3: finalize every block (concurrently).
    let jobs: Vec<(String, Vec<Value>)> = (0..nb)
        .map(|b| {
            let (s, e) = plan.range(b);
            let name = if b == 0 { &arts.finalize_first } else { &arts.finalize_mid };
            let mut inputs = marshal_block(hmm, &ys[s..e], arts.block_len);
            inputs.push(mat_to_f32(&prefixes[b].mat));
            inputs.push(mat_to_f32(&suffixes[b].mat));
            (name.clone(), inputs)
        })
        .collect();
    let mut gamma = vec![0.0f64; t * d];
    for (b, r) in exec.run_many(jobs).into_iter().enumerate() {
        let out = r?;
        let g = out[0].as_f32()?;
        let (s, e) = plan.range(b);
        for k in s..e {
            let row = &mut gamma[k * d..(k + 1) * d];
            for st in 0..d {
                row[st] = g[(k - s) * d + st] as f64;
            }
            normalize_sum(row);
        }
    }

    Ok((Posterior::new(d, gamma, loglik), 2 * nb))
}

/// Run the sharded max-product MAP estimator.
pub fn mp_sharded(
    exec: &dyn ArtifactExec,
    arts: &ShardedArtifacts,
    hmm: &Hmm,
    ys: &[u32],
) -> Result<(MapEstimate, usize)> {
    let d = hmm.num_states();
    let t = ys.len();
    let plan = BlockPlan::new(t, arts.block_len);
    let nb = plan.num_blocks();
    let op = MpOp { d };

    let jobs: Vec<(String, Vec<Value>)> = (0..nb)
        .map(|b| {
            let (s, e) = plan.range(b);
            let name = if b == 0 { &arts.fold_first } else { &arts.fold_mid };
            (name.clone(), marshal_block(hmm, &ys[s..e], arts.block_len))
        })
        .collect();
    let folds: Vec<MpElement> = exec
        .run_many(jobs)
        .into_iter()
        .map(|r| {
            let out = r?;
            Ok(MpElement { mat: mat_from_f32(out[0].as_f32()?, d) })
        })
        .collect::<Result<_>>()?;

    let mut prefixes = Vec::with_capacity(nb);
    let mut acc = op.identity();
    for f in &folds {
        prefixes.push(acc.clone());
        acc = op.combine(&acc, f);
    }
    let log_prob = acc
        .mat
        .row(0)
        .iter()
        .fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    let mut suffixes = vec![op.identity(); nb];
    let mut acc = mp_terminal(d);
    for b in (0..nb).rev() {
        suffixes[b] = acc.clone();
        acc = op.combine(&folds[b], &acc);
    }

    let jobs: Vec<(String, Vec<Value>)> = (0..nb)
        .map(|b| {
            let (s, e) = plan.range(b);
            let name = if b == 0 { &arts.finalize_first } else { &arts.finalize_mid };
            let mut inputs = marshal_block(hmm, &ys[s..e], arts.block_len);
            inputs.push(mat_to_f32(&prefixes[b].mat));
            inputs.push(mat_to_f32(&suffixes[b].mat));
            (name.clone(), inputs)
        })
        .collect();
    let mut path = vec![0u32; t];
    for (b, r) in exec.run_many(jobs).into_iter().enumerate() {
        let out = r?;
        let p = out[0].as_i32()?;
        let (s, e) = plan.range(b);
        for k in s..e {
            let v = p[k - s];
            if v < 0 || v as usize >= d {
                return Err(Error::xla(format!("block {b}: state {v} out of range")));
            }
            path[k] = v as u32;
        }
    }

    Ok((MapEstimate { path, log_prob }, 2 * nb))
}

/// Native mock executor used by unit tests (and the `--no-xla` path):
/// runs the fold/finalize semantics with the native element algebra.
pub struct NativeExec {
    /// The model whose element algebra the mock executes with.
    pub hmm: Hmm,
}

impl ArtifactExec for NativeExec {
    fn run(&self, artifact: &str, inputs: Vec<Value>) -> Result<Vec<Value>> {
        let d = self.hmm.num_states();
        let ys_pad = inputs[3].as_i32()?;
        let valid = inputs[4].as_f32()?;
        let n_valid = valid.iter().filter(|&&v| v > 0.5).count();
        let ys: Vec<u32> = ys_pad[..n_valid].iter().map(|&y| y as u32).collect();
        let first = artifact.contains("first");
        if artifact.contains("sp_block_fold") {
            let elems = chain_sp(&self.hmm, &ys, first);
            let op = SpOp { d };
            let mut acc = op.identity();
            for e in &elems {
                acc = op.combine(&acc, e);
            }
            Ok(vec![mat_to_f32(&acc.mat), Value::scalar_f32(acc.log_scale as f32)])
        } else if artifact.contains("mp_block_fold") {
            let elems = chain_mp(&self.hmm, &ys, first);
            let op = MpOp { d };
            let mut acc = op.identity();
            for e in &elems {
                acc = op.combine(&acc, e);
            }
            Ok(vec![mat_to_f32(&acc.mat)])
        } else if artifact.contains("sp_block_finalize") {
            let fin = mat_from_f32(inputs[5].as_f32()?, d);
            let bin = mat_from_f32(inputs[6].as_f32()?, d);
            let elems = chain_sp(&self.hmm, &ys, first);
            let op = SpOp { d };
            let pref = crate::scan::seq_scan(&op, &elems);
            let mut shifted: Vec<SpElement> = elems[1..].to_vec();
            shifted.push(SpOp { d }.identity());
            let suf = crate::scan::seq_scan_rev(&op, &shifted);
            let l = inputs[3].len();
            let mut gamma = vec![0.0f32; l * d];
            let fin_e = SpElement { mat: fin, log_scale: 0.0 };
            let bin_e = SpElement { mat: bin, log_scale: 0.0 };
            for k in 0..ys.len() {
                let gf = op.combine(&fin_e, &pref[k]);
                let gb = op.combine(&suf[k], &bin_e);
                let mut row: Vec<f64> =
                    (0..d).map(|s| gf.mat[(0, s)] * gb.mat[(s, 0)]).collect();
                normalize_sum(&mut row);
                for s in 0..d {
                    gamma[k * d + s] = row[s] as f32;
                }
            }
            Ok(vec![Value::F32(gamma, vec![l, d])])
        } else if artifact.contains("mp_block_finalize") {
            let fin = mat_from_f32(inputs[5].as_f32()?, d);
            let bin = mat_from_f32(inputs[6].as_f32()?, d);
            let elems = chain_mp(&self.hmm, &ys, first);
            let op = MpOp { d };
            let pref = crate::scan::seq_scan(&op, &elems);
            let mut shifted: Vec<MpElement> = elems[1..].to_vec();
            shifted.push(op.identity());
            let suf = crate::scan::seq_scan_rev(&op, &shifted);
            let l = inputs[3].len();
            let mut path = vec![0i32; l];
            let fin_e = MpElement { mat: fin };
            let bin_e = MpElement { mat: bin };
            for k in 0..ys.len() {
                let gf = op.combine(&fin_e, &pref[k]);
                let gb = op.combine(&suf[k], &bin_e);
                let delta: Vec<f64> =
                    (0..d).map(|s| gf.mat[(0, s)] + gb.mat[(s, 0)]).collect();
                path[k] = argmax(&delta) as i32;
            }
            Ok(vec![Value::I32(path, vec![l])])
        } else {
            Err(Error::artifact(format!("NativeExec: unknown '{artifact}'")))
        }
    }
}

fn chain_sp(hmm: &Hmm, ys: &[u32], first: bool) -> Vec<SpElement> {
    let mut elems = crate::elements::sp_element_chain(hmm, ys);
    if !first {
        // interior block: element 0 is the uniform Π ∘ e form
        let d = hmm.num_states();
        let e = hmm.emission_col(ys[0]);
        let pi = hmm.transition();
        let mut mat = Mat::zeros(d, d);
        for r in 0..d {
            for c in 0..d {
                mat[(r, c)] = pi[(r, c)] * e[c];
            }
        }
        elems[0] = SpElement::from_mat(mat);
    }
    elems
}

fn chain_mp(hmm: &Hmm, ys: &[u32], first: bool) -> Vec<MpElement> {
    let mut elems = crate::elements::mp_element_chain(hmm, ys);
    if !first {
        let d = hmm.num_states();
        let e = hmm.emission_col(ys[0]);
        let pi = hmm.transition();
        let mut mat = Mat::zeros(d, d);
        for r in 0..d {
            for c in 0..d {
                mat[(r, c)] = crate::elements::safe_ln(pi[(r, c)] * e[c]);
            }
        }
        elems[0] = MpElement { mat };
    }
    elems
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, sample, GeParams};
    use crate::rng::Xoshiro256StarStar;

    fn arts(block_len: usize) -> ShardedArtifacts {
        ShardedArtifacts {
            fold_first: "sp_block_fold_first".into(),
            fold_mid: "sp_block_fold_mid".into(),
            finalize_first: "sp_block_finalize_first".into(),
            finalize_mid: "sp_block_finalize_mid".into(),
            block_len,
        }
    }

    fn mp_arts(block_len: usize) -> ShardedArtifacts {
        ShardedArtifacts {
            fold_first: "mp_block_fold_first".into(),
            fold_mid: "mp_block_fold_mid".into(),
            finalize_first: "mp_block_finalize_first".into(),
            finalize_mid: "mp_block_finalize_mid".into(),
            block_len,
        }
    }

    #[test]
    fn marshal_pads_and_masks() {
        let hmm = gilbert_elliott(GeParams::default());
        let vals = marshal_block(&hmm, &[1, 0, 1], 8);
        assert_eq!(vals.len(), 5);
        assert_eq!(vals[3].as_i32().unwrap(), &[1, 0, 1, 0, 0, 0, 0, 0]);
        assert_eq!(
            vals[4].as_f32().unwrap(),
            &[1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn sp_sharded_matches_flat_native_exec() {
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(41);
        let tr = sample(&hmm, 300, &mut rng);
        let exec = NativeExec { hmm: hmm.clone() };
        for block in [64usize, 100, 300, 512] {
            let (post, calls) =
                sp_sharded(&exec, &arts(block), &hmm, &tr.observations).unwrap();
            assert_eq!(calls, 2 * 300usize.div_ceil(block));
            let flat = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
            // NativeExec round-trips through f32 (as the artifacts do),
            // so comparison is at single precision.
            let rel = (post.log_likelihood() - flat.log_likelihood()).abs()
                / flat.log_likelihood().abs();
            assert!(rel < 1e-5, "block={block} loglik rel {rel}");
            for k in 0..300 {
                for s in 0..4 {
                    assert!(
                        (post.gamma(k)[s] - flat.gamma(k)[s]).abs() < 1e-4,
                        "block={block} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn mp_sharded_matches_viterbi_native_exec() {
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(42);
        let tr = sample(&hmm, 250, &mut rng);
        let exec = NativeExec { hmm: hmm.clone() };
        let vit = crate::inference::viterbi(&hmm, &tr.observations).unwrap();
        for block in [32usize, 100, 250] {
            let (est, _) =
                mp_sharded(&exec, &mp_arts(block), &hmm, &tr.observations).unwrap();
            let rel = (est.log_prob - vit.log_prob).abs() / vit.log_prob.abs();
            assert!(rel < 1e-5, "block={block} logp rel {rel}");
            assert_eq!(est.path.len(), 250);
        }
    }
}

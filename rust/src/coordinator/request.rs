//! Request/response types for the decode service.

use crate::inference::{MapEstimate, Posterior};

/// Which inference task to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Smoothing marginals p(x_k | y_{1:T}) — sum-product family.
    Smooth,
    /// MAP path — max-product / Viterbi family.
    Map,
    /// Smoothing via the Bayesian (filter + RTS) formulation.
    BayesSmooth,
}

impl Algo {
    /// The parallel core-artifact entry serving this task.
    pub fn par_entry(self) -> &'static str {
        match self {
            Algo::Smooth => "sp_par",
            Algo::Map => "mp_par",
            Algo::BayesSmooth => "bs_par",
        }
    }

    /// The sequential core-artifact entry (ablation / router fallback).
    pub fn seq_entry(self) -> &'static str {
        match self {
            Algo::Smooth => "sp_seq",
            Algo::Map => "viterbi",
            Algo::BayesSmooth => "bs_seq",
        }
    }
}

/// How the router may execute a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Router decides: PJRT artifact when one fits, sharded beyond the
    /// largest artifact, native as last resort.
    #[default]
    Auto,
    /// Force the native-Rust algorithm library.
    Native,
    /// Force a (possibly padded) PJRT core artifact; error if none fits.
    Pjrt,
    /// Force the §V-B sharded plan; error if block artifacts are absent.
    Sharded,
}

/// A decode request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the response.
    pub id: u64,
    /// Model registry key.
    pub model: String,
    /// Observation symbols (length T ≥ 1).
    pub ys: Vec<u32>,
    pub algo: Algo,
    pub mode: ExecMode,
}

impl DecodeRequest {
    pub fn new(id: u64, model: impl Into<String>, ys: Vec<u32>, algo: Algo) -> Self {
        Self { id, model: model.into(), ys, algo, mode: ExecMode::Auto }
    }

    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }
}

/// Decode output payload.
#[derive(Debug, Clone)]
pub enum DecodeResult {
    Posterior(Posterior),
    Map(MapEstimate),
}

impl DecodeResult {
    pub fn as_posterior(&self) -> Option<&Posterior> {
        match self {
            DecodeResult::Posterior(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&MapEstimate> {
        match self {
            DecodeResult::Map(m) => Some(m),
            _ => None,
        }
    }
}

/// A served response.
#[derive(Debug, Clone)]
pub struct DecodeResponse {
    pub id: u64,
    pub result: DecodeResult,
    /// Human-readable description of the plan that served the request
    /// ("pjrt:sp_par_T1024_D4_M2 pad=24", "sharded:blocks=8", "native").
    pub plan: String,
    /// Wall time spent executing the plan.
    pub elapsed: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_names() {
        assert_eq!(Algo::Smooth.par_entry(), "sp_par");
        assert_eq!(Algo::Map.par_entry(), "mp_par");
        assert_eq!(Algo::BayesSmooth.par_entry(), "bs_par");
        assert_eq!(Algo::Map.seq_entry(), "viterbi");
    }

    #[test]
    fn request_builder() {
        let r = DecodeRequest::new(7, "ge", vec![0, 1], Algo::Map)
            .with_mode(ExecMode::Native);
        assert_eq!(r.id, 7);
        assert_eq!(r.mode, ExecMode::Native);
    }
}

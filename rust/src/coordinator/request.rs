//! Request/response types for the decode service.
//!
//! The task taxonomy here is a *view* over [`engine::Algorithm`]
//! — the single source of truth for algorithm names and entry points —
//! collapsed to what a decode client chooses between: smoothing
//! marginals, a MAP path, or the Bayesian-smoother formulation.

use crate::engine::Algorithm;
use crate::inference::{MapEstimate, Posterior};
use crate::jsonx::Json;

/// Which inference task to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Smoothing marginals p(x_k | y_{1:T}) — sum-product family.
    Smooth,
    /// MAP path — max-product / Viterbi family.
    Map,
    /// Smoothing via the Bayesian (filter + RTS) formulation.
    BayesSmooth,
}

impl Algo {
    /// Every task, for exhaustive round-trip tests.
    pub const ALL: [Algo; 3] = [Algo::Smooth, Algo::Map, Algo::BayesSmooth];

    /// The parallel-scan algorithm serving this task.
    pub fn parallel(self) -> Algorithm {
        match self {
            Algo::Smooth => Algorithm::SpPar,
            Algo::Map => Algorithm::MpPar,
            Algo::BayesSmooth => Algorithm::BsPar,
        }
    }

    /// The sequential algorithm serving this task.
    pub fn sequential(self) -> Algorithm {
        match self {
            Algo::Smooth => Algorithm::SpSeq,
            Algo::Map => Algorithm::Viterbi,
            Algo::BayesSmooth => Algorithm::BsSeq,
        }
    }

    /// The task an algorithm belongs to (`None` for training — it is not
    /// a decode task).
    pub fn from_algorithm(alg: Algorithm) -> Option<Algo> {
        match alg {
            Algorithm::SpSeq | Algorithm::SpPar => Some(Algo::Smooth),
            Algorithm::BsSeq | Algorithm::BsPar => Some(Algo::BayesSmooth),
            Algorithm::Viterbi | Algorithm::MpSeq | Algorithm::MpPar
            | Algorithm::MpPathPar => Some(Algo::Map),
            Algorithm::BaumWelch => None,
        }
    }

    /// The parallel core-artifact entry serving this task.
    pub fn par_entry(self) -> &'static str {
        self.parallel().name()
    }

    /// The sequential core-artifact entry (ablation / router fallback).
    pub fn seq_entry(self) -> &'static str {
        self.sequential().name()
    }

    /// Stable wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            Algo::Smooth => "smooth",
            Algo::Map => "map",
            Algo::BayesSmooth => "bayes",
        }
    }

    /// Inverse of [`as_str`](Self::as_str).
    pub fn parse(s: &str) -> Option<Algo> {
        Algo::ALL.into_iter().find(|a| a.as_str() == s)
    }

    /// jsonx serialization (the stable wire name).
    pub fn to_json(self) -> Json {
        Json::Str(self.as_str().to_string())
    }

    /// Inverse of [`to_json`](Self::to_json).
    pub fn from_json(v: &Json) -> Option<Algo> {
        v.as_str().and_then(Algo::parse)
    }
}

/// How the router may execute a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Router decides: PJRT artifact when one fits, sharded beyond the
    /// largest artifact, native as last resort.
    #[default]
    Auto,
    /// Force the native-Rust algorithm library.
    Native,
    /// Force a (possibly padded) PJRT core artifact; error if none fits.
    Pjrt,
    /// Force the §V-B sharded plan; error if block artifacts are absent.
    Sharded,
}

/// A decode request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the response.
    pub id: u64,
    /// Model registry key.
    pub model: String,
    /// Observation symbols (length T ≥ 1).
    pub ys: Vec<u32>,
    pub algo: Algo,
    pub mode: ExecMode,
}

impl DecodeRequest {
    pub fn new(id: u64, model: impl Into<String>, ys: Vec<u32>, algo: Algo) -> Self {
        Self { id, model: model.into(), ys, algo, mode: ExecMode::Auto }
    }

    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }
}

/// Decode output payload.
#[derive(Debug, Clone)]
pub enum DecodeResult {
    Posterior(Posterior),
    Map(MapEstimate),
}

impl DecodeResult {
    pub fn as_posterior(&self) -> Option<&Posterior> {
        match self {
            DecodeResult::Posterior(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&MapEstimate> {
        match self {
            DecodeResult::Map(m) => Some(m),
            _ => None,
        }
    }
}

/// A served response.
#[derive(Debug, Clone)]
pub struct DecodeResponse {
    pub id: u64,
    pub result: DecodeResult,
    /// Human-readable description of the plan that served the request
    /// ("pjrt:sp_par_T1024_D4_M2 pad=24", "sharded:blocks=8", "native").
    pub plan: String,
    /// Wall time spent executing the plan.
    pub elapsed: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_names() {
        assert_eq!(Algo::Smooth.par_entry(), Algorithm::SpPar.name());
        assert_eq!(Algo::Map.par_entry(), Algorithm::MpPar.name());
        assert_eq!(Algo::BayesSmooth.par_entry(), Algorithm::BsPar.name());
        assert_eq!(Algo::Map.seq_entry(), Algorithm::Viterbi.name());
    }

    #[test]
    fn algorithm_round_trip_exhaustive() {
        // Task → algorithm → task closes for both variants of each task.
        for algo in Algo::ALL {
            assert_eq!(Algo::from_algorithm(algo.parallel()), Some(algo));
            assert_eq!(Algo::from_algorithm(algo.sequential()), Some(algo));
            assert!(algo.parallel().is_parallel());
            assert!(!algo.sequential().is_parallel());
        }
        // Every non-training algorithm maps to exactly one task.
        for alg in Algorithm::ALL {
            match Algo::from_algorithm(alg) {
                Some(_) => assert_ne!(alg, Algorithm::BaumWelch),
                None => assert_eq!(alg, Algorithm::BaumWelch),
            }
        }
    }

    #[test]
    fn json_round_trip_exhaustive() {
        for algo in Algo::ALL {
            assert_eq!(Algo::from_json(&algo.to_json()), Some(algo));
            assert_eq!(Algo::parse(algo.as_str()), Some(algo));
        }
        assert_eq!(Algo::parse("nope"), None);
        assert_eq!(Algo::from_json(&Json::Num(1.0)), None);
    }

    #[test]
    fn request_builder() {
        let r = DecodeRequest::new(7, "ge", vec![0, 1], Algo::Map)
            .with_mode(ExecMode::Native);
        assert_eq!(r.id, 7);
        assert_eq!(r.mode, ExecMode::Native);
    }
}

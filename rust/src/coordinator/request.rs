//! Request/response types for the decode service.
//!
//! The task taxonomy here is a *view* over [`Algorithm`]
//! — the single source of truth for algorithm names and entry points —
//! collapsed to what a decode client chooses between: smoothing
//! marginals, a MAP path, or the Bayesian-smoother formulation.

use crate::engine::{Algorithm, Filtered, LagSmoothed, SessionOptions};
use crate::inference::{MapEstimate, Posterior};
use crate::jsonx::Json;

/// Which inference task to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Smoothing marginals p(x_k | y_{1:T}) — sum-product family.
    Smooth,
    /// MAP path — max-product / Viterbi family.
    Map,
    /// Smoothing via the Bayesian (filter + RTS) formulation.
    BayesSmooth,
}

impl Algo {
    /// Every task, for exhaustive round-trip tests.
    pub const ALL: [Algo; 3] = [Algo::Smooth, Algo::Map, Algo::BayesSmooth];

    /// The parallel-scan algorithm serving this task.
    pub fn parallel(self) -> Algorithm {
        match self {
            Algo::Smooth => Algorithm::SpPar,
            Algo::Map => Algorithm::MpPar,
            Algo::BayesSmooth => Algorithm::BsPar,
        }
    }

    /// The sequential algorithm serving this task.
    pub fn sequential(self) -> Algorithm {
        match self {
            Algo::Smooth => Algorithm::SpSeq,
            Algo::Map => Algorithm::Viterbi,
            Algo::BayesSmooth => Algorithm::BsSeq,
        }
    }

    /// The task an algorithm belongs to (`None` for training and for
    /// the Kalman tier — neither is a discrete decode task; Kalman
    /// traffic flows through `SessionKind::Kalman` stream verbs, not
    /// one-shot decode requests).
    pub fn from_algorithm(alg: Algorithm) -> Option<Algo> {
        match alg {
            Algorithm::SpSeq | Algorithm::SpPar => Some(Algo::Smooth),
            Algorithm::BsSeq | Algorithm::BsPar => Some(Algo::BayesSmooth),
            Algorithm::Viterbi | Algorithm::MpSeq | Algorithm::MpPar
            | Algorithm::MpPathPar => Some(Algo::Map),
            Algorithm::BaumWelch | Algorithm::KfSeq | Algorithm::KfPar
            | Algorithm::KsSeq | Algorithm::KsPar => None,
        }
    }

    /// The parallel core-artifact entry serving this task.
    pub fn par_entry(self) -> &'static str {
        self.parallel().name()
    }

    /// The sequential core-artifact entry (ablation / router fallback).
    pub fn seq_entry(self) -> &'static str {
        self.sequential().name()
    }

    /// Stable wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            Algo::Smooth => "smooth",
            Algo::Map => "map",
            Algo::BayesSmooth => "bayes",
        }
    }

    /// Inverse of [`as_str`](Self::as_str).
    pub fn parse(s: &str) -> Option<Algo> {
        Algo::ALL.into_iter().find(|a| a.as_str() == s)
    }

    /// jsonx serialization (the stable wire name).
    pub fn to_json(self) -> Json {
        Json::Str(self.as_str().to_string())
    }

    /// Inverse of [`to_json`](Self::to_json).
    pub fn from_json(v: &Json) -> Option<Algo> {
        v.as_str().and_then(Algo::parse)
    }
}

/// How the router may execute a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Router decides: PJRT artifact when one fits, sharded beyond the
    /// largest artifact, native as last resort.
    #[default]
    Auto,
    /// Force the native-Rust algorithm library.
    Native,
    /// Force a (possibly padded) PJRT core artifact; error if none fits.
    Pjrt,
    /// Force the §V-B sharded plan; error if block artifacts are absent.
    Sharded,
}

/// A decode request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the response.
    pub id: u64,
    /// Model registry key.
    pub model: String,
    /// Observation symbols (length T ≥ 1).
    pub ys: Vec<u32>,
    /// Which inference task to run.
    pub algo: Algo,
    /// Execution-plan constraint (default: router's choice).
    pub mode: ExecMode,
}

impl DecodeRequest {
    /// A request in [`ExecMode::Auto`].
    pub fn new(id: u64, model: impl Into<String>, ys: Vec<u32>, algo: Algo) -> Self {
        Self { id, model: model.into(), ys, algo, mode: ExecMode::Auto }
    }

    /// Constrain the execution plan (builder-style).
    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }
}

/// Decode output payload.
#[derive(Debug, Clone)]
pub enum DecodeResult {
    /// Smoothing marginals (the sum-product / Bayesian tasks).
    Posterior(Posterior),
    /// MAP path estimate (the max-product task).
    Map(MapEstimate),
}

impl DecodeResult {
    /// The posterior payload, when this is a smoothing result.
    pub fn as_posterior(&self) -> Option<&Posterior> {
        match self {
            DecodeResult::Posterior(p) => Some(p),
            _ => None,
        }
    }

    /// The MAP payload, when this is a decode result.
    pub fn as_map(&self) -> Option<&MapEstimate> {
        match self {
            DecodeResult::Map(m) => Some(m),
            _ => None,
        }
    }
}

/// Streaming session verbs — the open → append* → close protocol served
/// by `Coordinator::stream` and the serve loop.
#[derive(Debug, Clone)]
pub enum StreamVerb {
    /// Create a session bound to a registered model. `lag` > 0 makes
    /// every append also return a fixed-lag smoothing window of that
    /// width (0 = filtering only); the coordinator rejects lags above
    /// `CoordinatorConfig::max_stream_lag` (appends run an O(lag +
    /// block) query on the serve loop).
    Open {
        /// Model registry key to bind the session to.
        model: String,
        /// Session options (checkpoint block, MAP tracking, kind).
        options: SessionOptions,
        /// Fixed-lag smoothing width returned on every append (0 =
        /// filtering only).
        lag: usize,
    },
    /// Ingest observations into an open session. Evicted sessions are
    /// transparently restored from the session store first.
    Append {
        /// Target session id (from [`StreamReply::Opened`]).
        session: u64,
        /// Observation chunk to append (may be empty — a poll).
        ys: Vec<u32>,
    },
    /// Report residency for one session plus coordinator-wide gauges —
    /// cheap (no restore is triggered).
    Stat {
        /// Target session id.
        session: u64,
    },
    /// Produce the exact full-sequence posterior and remove the session
    /// (restoring it first when evicted).
    Close {
        /// Target session id.
        session: u64,
    },
    /// Create a session under a **caller-chosen** id — the cluster
    /// router's placement verb: the router allocates ids so a session
    /// keeps one identity across every worker it may live on. Fails if
    /// the id is already registered; the coordinator advances its own
    /// allocator past `session` so local opens never collide.
    OpenAt {
        /// Caller-chosen session id to register.
        session: u64,
        /// Model registry key to bind the session to.
        model: String,
        /// Session options (checkpoint block, MAP tracking, kind).
        options: SessionOptions,
        /// Fixed-lag smoothing width returned on every append (0 =
        /// filtering only).
        lag: usize,
    },
    /// Capture a migration snapshot of one session: compact its state
    /// into a single [`Session::snapshot`](crate::engine::Session)
    /// checkpoint and return it with the session's meta — the
    /// compact-on-A half of live migration. The session stays open and
    /// servable on this worker until [`StreamVerb::Release`].
    Export {
        /// Target session id.
        session: u64,
    },
    /// Register a session from an exported snapshot — the restore-on-B
    /// half of live migration. The restored session is bit-identical to
    /// the exported one (the snapshot/resume contract). Fails if the id
    /// is already registered or the model/fingerprint doesn't match a
    /// registered model.
    Import {
        /// Session id to register (the exported session's id).
        session: u64,
        /// The exported session's durable meta (model, options, lag).
        meta: crate::store::SessionMeta,
        /// The exported [`Session::snapshot`](crate::engine::Session)
        /// JSON.
        snapshot: Json,
    },
    /// Remove a session *without* finishing it — the cut-over step of
    /// migration (the source copy is released once the destination
    /// verifies). No posterior is computed.
    Release {
        /// Target session id.
        session: u64,
    },
}

/// A streaming request (see [`StreamVerb`]).
#[derive(Debug, Clone)]
pub struct StreamRequest {
    /// Caller-chosen id, echoed in the response.
    pub id: u64,
    /// The verb to serve.
    pub verb: StreamVerb,
}

impl StreamRequest {
    /// An [`StreamVerb::Open`] with default session options.
    pub fn open(id: u64, model: impl Into<String>, lag: usize) -> Self {
        Self {
            id,
            verb: StreamVerb::Open {
                model: model.into(),
                options: SessionOptions::default(),
                lag,
            },
        }
    }

    /// An [`StreamVerb::Append`] of one observation chunk.
    pub fn append(id: u64, session: u64, ys: Vec<u32>) -> Self {
        Self { id, verb: StreamVerb::Append { session, ys } }
    }

    /// A [`StreamVerb::Stat`] residency probe.
    pub fn stat(id: u64, session: u64) -> Self {
        Self { id, verb: StreamVerb::Stat { session } }
    }

    /// A [`StreamVerb::Close`] for the exact posterior.
    pub fn close(id: u64, session: u64) -> Self {
        Self { id, verb: StreamVerb::Close { session } }
    }

    /// A [`StreamVerb::OpenAt`] placement under a caller-chosen id.
    pub fn open_at(
        id: u64,
        session: u64,
        model: impl Into<String>,
        options: SessionOptions,
        lag: usize,
    ) -> Self {
        Self {
            id,
            verb: StreamVerb::OpenAt {
                session,
                model: model.into(),
                options,
                lag,
            },
        }
    }

    /// A [`StreamVerb::Export`] migration-snapshot request.
    pub fn export(id: u64, session: u64) -> Self {
        Self { id, verb: StreamVerb::Export { session } }
    }

    /// A [`StreamVerb::Import`] restore from an exported snapshot.
    pub fn import(
        id: u64,
        session: u64,
        meta: crate::store::SessionMeta,
        snapshot: Json,
    ) -> Self {
        Self { id, verb: StreamVerb::Import { session, meta, snapshot } }
    }

    /// A [`StreamVerb::Release`] removal without finish.
    pub fn release(id: u64, session: u64) -> Self {
        Self { id, verb: StreamVerb::Release { session } }
    }
}

/// Streaming reply payload, shaped by the verb.
#[derive(Debug, Clone)]
pub enum StreamReply {
    /// The session is open and durable; its id serves every later verb.
    Opened {
        /// Coordinator-assigned session id.
        session: u64,
    },
    /// One append was applied (and durably logged, disk stores).
    Appended {
        /// Echo of the target session id.
        session: u64,
        /// Observations held by the session after this append.
        len: usize,
        /// Filtering marginal + running log-likelihood after the append.
        filtered: Filtered,
        /// Fixed-lag smoothing window (sessions opened with `lag` > 0).
        window: Option<LagSmoothed>,
        /// Router observability: the core artifact that could serve the
        /// suffix window once the XLA-backed rescan lands (ROADMAP);
        /// execution today is native.
        plan_hint: Option<String>,
    },
    /// Residency report for one session ([`StreamVerb::Stat`]).
    Stats {
        /// Echo of the target session id.
        session: u64,
        /// Observations held (resident or spilled).
        len: usize,
        /// Whether the session's element chain is in RAM right now.
        resident: bool,
        /// Model the session is bound to.
        model: String,
        /// Coordinator-wide gauge: sessions registered (any residency).
        open_sessions: usize,
        /// Coordinator-wide gauge: sessions currently resident.
        resident_sessions: usize,
    },
    /// The session is finished and removed everywhere.
    Closed {
        /// Echo of the target session id.
        session: u64,
        /// Exact full-sequence posterior, bit-identical to the one-shot
        /// parallel smoother under the session's scan options.
        posterior: Posterior,
    },
    /// Migration snapshot of one session ([`StreamVerb::Export`]).
    Exported {
        /// Echo of the target session id.
        session: u64,
        /// Observations the snapshot covers.
        len: usize,
        /// The session's durable meta (model, options, lag,
        /// fingerprint).
        meta: crate::store::SessionMeta,
        /// The [`Session::snapshot`](crate::engine::Session) JSON —
        /// resume it elsewhere for a bit-identical session.
        snapshot: Json,
    },
    /// A session was registered from a snapshot
    /// ([`StreamVerb::Import`]).
    Imported {
        /// Echo of the imported session id.
        session: u64,
        /// Observations the restored session holds.
        len: usize,
    },
    /// A session was removed without finishing
    /// ([`StreamVerb::Release`]).
    Released {
        /// Echo of the released session id.
        session: u64,
    },
}

/// A served streaming response.
#[derive(Debug, Clone)]
pub struct StreamResponse {
    /// Echo of the request id.
    pub id: u64,
    /// Verb-shaped payload.
    pub reply: StreamReply,
    /// Wall time spent serving the verb.
    pub elapsed: std::time::Duration,
}

/// A served response.
#[derive(Debug, Clone)]
pub struct DecodeResponse {
    /// Echo of the request id.
    pub id: u64,
    /// The decode payload (posterior or MAP path).
    pub result: DecodeResult,
    /// Human-readable description of the plan that served the request
    /// ("pjrt:sp_par_T1024_D4_M2 pad=24", "sharded:blocks=8", "native").
    pub plan: String,
    /// Wall time spent executing the plan.
    pub elapsed: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_names() {
        assert_eq!(Algo::Smooth.par_entry(), Algorithm::SpPar.name());
        assert_eq!(Algo::Map.par_entry(), Algorithm::MpPar.name());
        assert_eq!(Algo::BayesSmooth.par_entry(), Algorithm::BsPar.name());
        assert_eq!(Algo::Map.seq_entry(), Algorithm::Viterbi.name());
    }

    #[test]
    fn algorithm_round_trip_exhaustive() {
        // Task → algorithm → task closes for both variants of each task.
        for algo in Algo::ALL {
            assert_eq!(Algo::from_algorithm(algo.parallel()), Some(algo));
            assert_eq!(Algo::from_algorithm(algo.sequential()), Some(algo));
            assert!(algo.parallel().is_parallel());
            assert!(!algo.sequential().is_parallel());
        }
        // Every discrete decode algorithm maps to exactly one task;
        // training and the Kalman tier (session-only traffic) map to
        // none.
        use crate::engine::Task;
        for alg in Algorithm::ALL {
            match Algo::from_algorithm(alg) {
                Some(_) => assert!(
                    alg != Algorithm::BaumWelch && alg.task() != Task::Gaussian
                ),
                None => assert!(
                    alg == Algorithm::BaumWelch || alg.task() == Task::Gaussian
                ),
            }
        }
    }

    #[test]
    fn json_round_trip_exhaustive() {
        for algo in Algo::ALL {
            assert_eq!(Algo::from_json(&algo.to_json()), Some(algo));
            assert_eq!(Algo::parse(algo.as_str()), Some(algo));
        }
        assert_eq!(Algo::parse("nope"), None);
        assert_eq!(Algo::from_json(&Json::Num(1.0)), None);
    }

    #[test]
    fn request_builder() {
        let r = DecodeRequest::new(7, "ge", vec![0, 1], Algo::Map)
            .with_mode(ExecMode::Native);
        assert_eq!(r.id, 7);
        assert_eq!(r.mode, ExecMode::Native);
    }
}

//! The unified inference engine — one entry point for all nine
//! algorithms, pluggable backends, reusable workspaces.
//!
//! The paper's premise is that sequential and parallel sum-product /
//! max-product / Bayesian-smoother inference are *the same computation*
//! under different scan schedules. This module makes that premise the
//! API: a single [`Algorithm`] enum names every method, one
//! [`Engine::run`] executes any of them, and a [`Backend`] trait lets
//! the native library and the PJRT/XLA runtime sit behind the same call
//! (DESIGN.md §3).
//!
//! ```no_run
//! use hmm_scan::engine::{Algorithm, Engine};
//! use hmm_scan::hmm::{gilbert_elliott, GeParams};
//!
//! let mut engine = Engine::builder(gilbert_elliott(GeParams::default())).build();
//! let post = engine.run(Algorithm::SpPar, &[0, 1, 1, 0]).unwrap()
//!     .into_posterior().unwrap();
//! println!("log p(y) = {}", post.log_likelihood());
//! ```
//!
//! The engine owns a reusable [`Workspace`]: repeated `run` calls on the
//! serving hot path overwrite the per-call D×D element buffers in place
//! instead of reallocating them (see `benches/primitives.rs` for the
//! before/after). [`Engine::run_batch`] fans a multi-sequence request
//! out over `exec::parallel_for_chunks`, one workspace per worker.
//!
//! For online workloads, [`Engine::open_session`] returns a long-lived
//! [`Session`] whose checkpointed prefix scan makes appends O(k) and
//! fixed-lag queries O(lag + block) instead of the O(T) rerun the
//! one-shot API costs per arrival (see `engine::session`).

mod algorithm;
mod backend;
mod session;

#[cfg(test)]
mod tests;

pub use algorithm::{Algorithm, Task};
pub use backend::{decode_core_outputs, Backend, NativeBackend, XlaBackend};
pub use session::{
    Filtered, LagDecoded, LagSmoothed, Session, SessionKind, SessionOptions,
    DEFAULT_SESSION_BLOCK,
};
// Re-exported so custom `Backend` implementations outside this module
// can name the workspace type the trait signature uses.
pub use crate::inference::Workspace;

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::inference::{BaumWelchOptions, BaumWelchResult, MapEstimate, Posterior};
use crate::scan::ScanOptions;

/// The result of one [`Engine::run`] call — shaped by the algorithm's
/// [`Task`] family.
#[derive(Debug, Clone)]
pub enum EngineOutput {
    /// Smoothing marginals + log-likelihood.
    Posterior(Posterior),
    /// MAP state sequence + joint log-probability.
    Map(MapEstimate),
    /// Baum–Welch training result (boxed — it carries a full model).
    Training(Box<BaumWelchResult>),
}

impl EngineOutput {
    /// The posterior payload, when this is a smoothing result.
    pub fn as_posterior(&self) -> Option<&Posterior> {
        match self {
            EngineOutput::Posterior(p) => Some(p),
            _ => None,
        }
    }

    /// The MAP payload, when this is a decode result.
    pub fn as_map(&self) -> Option<&MapEstimate> {
        match self {
            EngineOutput::Map(m) => Some(m),
            _ => None,
        }
    }

    /// The training payload, when this is a Baum–Welch result.
    pub fn as_training(&self) -> Option<&BaumWelchResult> {
        match self {
            EngineOutput::Training(t) => Some(t),
            _ => None,
        }
    }

    /// Unwrap the posterior; typed error on any other output kind.
    pub fn into_posterior(self) -> Result<Posterior> {
        match self {
            EngineOutput::Posterior(p) => Ok(p),
            other => Err(Error::invalid_request(format!(
                "expected a smoothing posterior, got {}",
                other.kind()
            ))),
        }
    }

    /// Unwrap the MAP estimate; typed error on any other output kind.
    pub fn into_map(self) -> Result<MapEstimate> {
        match self {
            EngineOutput::Map(m) => Ok(m),
            other => Err(Error::invalid_request(format!(
                "expected a MAP estimate, got {}",
                other.kind()
            ))),
        }
    }

    /// Unwrap the training result; typed error on any other kind.
    pub fn into_training(self) -> Result<BaumWelchResult> {
        match self {
            EngineOutput::Training(t) => Ok(*t),
            other => Err(Error::invalid_request(format!(
                "expected a training result, got {}",
                other.kind()
            ))),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            EngineOutput::Posterior(_) => "posterior",
            EngineOutput::Map(_) => "map",
            EngineOutput::Training(_) => "training",
        }
    }
}

/// Builder for [`Engine`].
pub struct EngineBuilder {
    hmm: Arc<Hmm>,
    scan: ScanOptions,
    baum_welch: BaumWelchOptions,
    backend: Option<Arc<dyn Backend>>,
}

impl EngineBuilder {
    /// Threading/schedule options for the parallel-scan methods.
    pub fn scan_options(mut self, scan: ScanOptions) -> Self {
        self.scan = scan;
        self
    }

    /// Options for [`Algorithm::BaumWelch`] runs. The engine's scan
    /// options override the `scan` field at run time so all methods
    /// share one threading policy.
    pub fn baum_welch_options(mut self, opts: BaumWelchOptions) -> Self {
        self.baum_welch = opts;
        self
    }

    /// Execution backend (defaults to [`NativeBackend`]).
    pub fn backend(mut self, backend: Arc<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Finish the builder (native backend unless one was supplied).
    pub fn build(self) -> Engine {
        Engine {
            hmm: self.hmm,
            scan: self.scan,
            baum_welch: self.baum_welch,
            backend: self.backend.unwrap_or_else(|| Arc::new(NativeBackend)),
            ws: Workspace::default(),
        }
    }
}

/// The unified inference engine: owns a model, a backend, threading
/// options and a reusable scratch workspace.
pub struct Engine {
    hmm: Arc<Hmm>,
    scan: ScanOptions,
    baum_welch: BaumWelchOptions,
    backend: Arc<dyn Backend>,
    ws: Workspace,
}

impl Engine {
    /// Start building an engine for `hmm` (accepts `Hmm` or `Arc<Hmm>`).
    pub fn builder(hmm: impl Into<Arc<Hmm>>) -> EngineBuilder {
        EngineBuilder {
            hmm: hmm.into(),
            scan: ScanOptions::default(),
            baum_welch: BaumWelchOptions::default(),
            backend: None,
        }
    }

    /// The model this engine serves.
    pub fn hmm(&self) -> &Hmm {
        &self.hmm
    }

    /// The engine's threading/schedule options.
    pub fn scan_options(&self) -> ScanOptions {
        self.scan
    }

    /// Name of the execution backend ("native" / "xla").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Run one algorithm on one observation sequence.
    ///
    /// `&mut self` because the call reuses the engine's scratch
    /// workspace; results are bit-identical to the free functions (see
    /// `engine::tests`).
    pub fn run(&mut self, alg: Algorithm, ys: &[u32]) -> Result<EngineOutput> {
        let mut bw = self.baum_welch;
        bw.scan = self.scan;
        self.backend.run(&self.hmm, alg, ys, self.scan, bw, &mut self.ws)
    }

    /// Convenience: parallel smoothing marginals ([`Algorithm::SpPar`]).
    pub fn smooth(&mut self, ys: &[u32]) -> Result<Posterior> {
        self.run(Algorithm::SpPar, ys)?.into_posterior()
    }

    /// Convenience: parallel MAP decoding ([`Algorithm::MpPar`]).
    pub fn decode_map(&mut self, ys: &[u32]) -> Result<MapEstimate> {
        self.run(Algorithm::MpPar, ys)?.into_map()
    }

    /// Run one algorithm over many sequences, fanned out over
    /// `exec::parallel_for_chunks` with one scratch workspace per worker.
    ///
    /// The thread budget is split across the batch dimension first: each
    /// of the min(n, threads) workers runs its sequences with
    /// ⌊threads / n⌋ scan threads (serial once the batch saturates the
    /// cores), so the total never oversubscribes the machine. Results
    /// preserve input order, with per-sequence errors reported per slot.
    pub fn run_batch(
        &self,
        alg: Algorithm,
        seqs: &[Vec<u32>],
    ) -> Vec<Result<EngineOutput>> {
        let n = seqs.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = self.scan.threads.max(1);
        let per_seq_threads = (threads / n).max(1);
        let per_seq_scan = if per_seq_threads == 1 {
            ScanOptions { threads: 1, min_parallel_work: usize::MAX, ..self.scan }
        } else {
            ScanOptions { threads: per_seq_threads, ..self.scan }
        };
        let mut bw = self.baum_welch;
        bw.scan = per_seq_scan;

        let mut out: Vec<Option<Result<EngineOutput>>> = Vec::new();
        out.resize_with(n, || None);
        {
            let slots = crate::exec::SharedSliceMut::new(&mut out);
            let backend = &self.backend;
            let hmm = &self.hmm;
            crate::exec::parallel_for_chunks(n, threads, |_, lo, hi| {
                let mut ws = Workspace::default();
                for i in lo..hi {
                    let r = backend.run(hmm, alg, &seqs[i], per_seq_scan, bw, &mut ws);
                    // SAFETY: slot i is written by exactly one chunk
                    // (chunks partition 0..n).
                    unsafe { slots.write(i, Some(r)) };
                }
            });
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(Error::coordinator("batch slot lost"))))
            .collect()
    }
}

//! Pluggable execution backends for the [`Engine`](super::Engine).
//!
//! * [`NativeBackend`] — the in-process Rust algorithm library
//!   (`inference::*`), with workspace reuse on the parallel methods.
//! * [`XlaBackend`] — AOT-compiled PJRT artifacts executed through an
//!   [`ArtifactExec`] (the coordinator's `XlaPool` in production, native
//!   mocks in tests). Covers the compiled parallel cores; everything
//!   else reports a typed artifact error so callers can fall back.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::inference::{
    self, BaumWelchOptions, MapEstimate, Posterior, Workspace,
};
use crate::runtime::{marshal_block, ArtifactExec, Manifest, Value};
use crate::scan::ScanOptions;

use super::algorithm::{Algorithm, Task};
use super::EngineOutput;

/// A strategy for executing one inference request.
///
/// Implementations are stateless with respect to the call (scratch comes
/// in through the workspace), so one backend instance can be shared by
/// many engines.
pub trait Backend: Send + Sync {
    /// Short identifier for plans/metrics ("native", "xla").
    fn name(&self) -> &'static str;

    /// Execute `alg` on `ys` under model `hmm`.
    fn run(
        &self,
        hmm: &Hmm,
        alg: Algorithm,
        ys: &[u32],
        scan: ScanOptions,
        baum_welch: BaumWelchOptions,
        ws: &mut Workspace,
    ) -> Result<EngineOutput>;
}

/// The native-Rust algorithm library.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn run(
        &self,
        hmm: &Hmm,
        alg: Algorithm,
        ys: &[u32],
        scan: ScanOptions,
        baum_welch: BaumWelchOptions,
        ws: &mut Workspace,
    ) -> Result<EngineOutput> {
        Ok(match alg {
            Algorithm::SpSeq => EngineOutput::Posterior(inference::sp_seq(hmm, ys)?),
            Algorithm::SpPar => {
                EngineOutput::Posterior(inference::sp_par_ws(hmm, ys, scan, ws)?)
            }
            Algorithm::BsSeq => EngineOutput::Posterior(inference::bs_seq(hmm, ys)?),
            Algorithm::BsPar => {
                EngineOutput::Posterior(inference::bs_par_ws(hmm, ys, scan, ws)?)
            }
            Algorithm::Viterbi => EngineOutput::Map(inference::viterbi(hmm, ys)?),
            Algorithm::MpSeq => EngineOutput::Map(inference::mp_seq(hmm, ys)?),
            Algorithm::MpPar => {
                EngineOutput::Map(inference::mp_par_ws(hmm, ys, scan, ws)?)
            }
            Algorithm::MpPathPar => {
                EngineOutput::Map(inference::mp_path_par(hmm, ys, scan)?)
            }
            Algorithm::BaumWelch => EngineOutput::Training(Box::new(
                inference::baum_welch(hmm, ys, baum_welch)?,
            )),
            Algorithm::KfSeq | Algorithm::KfPar | Algorithm::KsSeq
            | Algorithm::KsPar => {
                return Err(Error::invalid_request(format!(
                    "{} runs on linear-Gaussian models — use \
                     kalman::KalmanEngine, not the discrete-HMM engine",
                    alg.name()
                )))
            }
        })
    }
}

/// PJRT-artifact execution: looks up the smallest compiled core artifact
/// covering the request (identity-element padding makes shorter
/// sequences exact — see `python/compile/model.py`) and decodes its
/// outputs into the same result types the native backend produces.
pub struct XlaBackend {
    exec: Arc<dyn ArtifactExec + Send + Sync>,
    manifest: Arc<Manifest>,
}

impl XlaBackend {
    /// A backend over an artifact executor and its manifest.
    pub fn new(
        exec: Arc<dyn ArtifactExec + Send + Sync>,
        manifest: Arc<Manifest>,
    ) -> Self {
        Self { exec, manifest }
    }

    /// The manifest of compiled artifacts this backend routes over.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute a specific core artifact of capacity `capacity` (resolved
    /// by the coordinator's router) and decode its outputs.
    pub fn run_artifact(
        &self,
        hmm: &Hmm,
        alg: Algorithm,
        ys: &[u32],
        artifact: &str,
        capacity: usize,
    ) -> Result<EngineOutput> {
        hmm.check_observations(ys)?;
        let t = ys.len();
        if t > capacity {
            return Err(Error::invalid_request(format!(
                "sequence length {t} exceeds artifact capacity {capacity}"
            )));
        }
        let inputs = marshal_block(hmm, ys, capacity);
        let out = self.exec.run(artifact, inputs)?;
        decode_core_outputs(alg.task(), hmm.num_states(), t, &out)
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn run(
        &self,
        hmm: &Hmm,
        alg: Algorithm,
        ys: &[u32],
        _scan: ScanOptions,
        _baum_welch: BaumWelchOptions,
        _ws: &mut Workspace,
    ) -> Result<EngineOutput> {
        hmm.check_observations(ys)?;
        let entry = alg.name();
        let (t, d, m) = (ys.len(), hmm.num_states(), hmm.num_symbols());
        let spec = self
            .manifest
            .smallest_covering(entry, t, d, m)
            .ok_or_else(|| {
                Error::artifact(format!(
                    "no core artifact covers T={t} (entry {entry}, D={d}, M={m})"
                ))
            })?;
        let (artifact, capacity) = (spec.name.clone(), spec.t);
        self.run_artifact(hmm, alg, ys, &artifact, capacity)
    }
}

/// Decode a core artifact's output tuple into an [`EngineOutput`].
///
/// Contract (`python/compile/aot.py`): smoothers return
/// `(gamma f32[capacity, D], loglik f32[])`; MAP cores return
/// `(path i32[capacity], log_prob f32[])`. Padding rows beyond `t` are
/// discarded.
pub fn decode_core_outputs(
    task: Task,
    d: usize,
    t: usize,
    out: &[Value],
) -> Result<EngineOutput> {
    if out.len() < 2 {
        return Err(Error::xla(format!(
            "core artifact returned {} outputs, expected 2",
            out.len()
        )));
    }
    match task {
        Task::Smoothing => {
            let g = out[0].as_f32()?;
            let loglik = out[1].scalar()?;
            if g.len() < t * d {
                return Err(Error::xla(format!(
                    "gamma output has {} values, need {}",
                    g.len(),
                    t * d
                )));
            }
            let gamma: Vec<f64> = g[..t * d].iter().map(|&v| v as f64).collect();
            Ok(EngineOutput::Posterior(Posterior::new(d, gamma, loglik)))
        }
        Task::MapDecoding => {
            let p = out[0].as_i32()?;
            let log_prob = out[1].scalar()?;
            if p.len() < t {
                return Err(Error::xla(format!(
                    "path output has {} values, need {t}",
                    p.len()
                )));
            }
            let path = p[..t]
                .iter()
                .map(|&v| {
                    if v < 0 || v as usize >= d {
                        Err(Error::xla(format!("state {v} out of range")))
                    } else {
                        Ok(v as u32)
                    }
                })
                .collect::<Result<Vec<u32>>>()?;
            Ok(EngineOutput::Map(MapEstimate { path, log_prob }))
        }
        Task::Training => {
            Err(Error::artifact("training has no compiled artifact path"))
        }
        Task::Gaussian => Err(Error::artifact(
            "the Kalman tier has no compiled artifact path",
        )),
    }
}

//! Streaming inference sessions — the online counterpart of
//! [`Engine::run`].
//!
//! A [`Session`] is a long-lived, resumable inference state: the paper's
//! prefix-scan formulation makes the running forward product an
//! associative prefix, so appending k observations costs O(k) summary
//! folds (via [`CheckpointedScan`]) instead of the O(T) rerun a
//! complete-sequence API forces on streaming clients.
//!
//! ```text
//!            push(&[y…])            push(&[y…])
//!  (empty) ────────────▶ streaming ────────────▶ streaming ─ … ─┐
//!                        │  │  │                                │
//!             filtered() │  │ smoothed_lag(L) / map_lag(L)      │ finish()
//!                O(1)    ▼  ▼     O(L + B)                      ▼   O(T)
//!              p(x_t|y_1:t)  window marginals / MAP      exact posterior
//! ```
//!
//! Cost model (T pushed so far, block length B, lag L):
//!
//! * `push` of k observations — k element builds + k fold steps, plus
//!   one carry combine per completed block: O(k · D³). Steady-state
//!   appends are allocation-free beyond the retained chain element —
//!   the fold step runs through the scan's op-owned scratch
//!   ([`AssocOp::fold_step`](crate::scan::AssocOp::fold_step)).
//! * `filtered` — one combine: O(D³).
//! * `smoothed_lag(L)` / `map_lag(L)` — forward suffix rescan of width
//!   ≤ L + B from the covering checkpoint, backward parallel scan over
//!   the window: O((L + B) · D³), independent of T.
//! * `finish` — materializes the forward scan from the checkpoints
//!   (phase 3 only: one rescan per block) plus the full backward scan:
//!   O(T · D³), **bit-identical** to `Engine::run(Algorithm::SpPar, ..)`
//!   under the same scan options (`finish_map` ↔ `Algorithm::MpPar`) —
//!   property-tested over random push splits in `engine::tests`.
//!
//! Sessions come in three element families ([`SessionKind`]): the
//! default sum-product sessions above; *Bayesian filtering* sessions
//! (`SessionKind::Bayes`) that stream the BS-Par element algebra of
//! Särkkä & García-Fernández — `push`/`filtered`/`finish` only, with
//! `finish` bit-identical to `Engine::run(Algorithm::BsPar, ..)`;
//! and *Kalman* sessions (`SessionKind::Kalman`) that stream the
//! affine-Gaussian element algebra of `crate::kalman` over a
//! linear-Gaussian model. Kalman sessions are opened through
//! [`crate::kalman::KalmanEngine::open_session`] (they carry an
//! [`Lgssm`], not an HMM) and ingest *encoded* observations — each f64
//! as two u32 words ([`crate::kalman::obs_to_words`]) — so they ride
//! the same u32 append channel as the discrete families end to end
//! (wire, store, router). Appends may split rows at any word boundary;
//! torn tails buffer until the row completes. `push`/`filtered`/
//! `finish` are served (`finish` = the full KS-Par smoother,
//! bit-identical to one-shot `kalman::ks_par` under the session's scan
//! options); fixed-lag and MAP queries return a typed error.
//!
//! Sessions snapshot to JSON ([`Session::snapshot`] /
//! [`Engine::resume_session`]): observations plus the serialized block
//! summaries, so a restore re-derives carries in O(T/B) combines and
//! skips the O(T · D³) refold. The snapshot doubles as the eviction
//! payload of the coordinator's session store (`store::SessionStore`):
//! a spilled session restores bit-identically from it.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::elements::serde::{
    bs_element_from_json, bs_element_to_json, check_bs_shape, check_kf_shape,
    check_sp_shape, f64s_from_hex, f64s_to_hex, kf_element_from_json,
    kf_element_to_json, obs_from_json, obs_to_json, sp_element_from_json,
    sp_element_to_json,
};
use crate::elements::{
    bs_element_chain, bs_element_protos, bs_prior_element, mp_element_protos,
    mp_prior_element, mp_terminal, sp_element_chain, sp_element_protos,
    sp_prior_element, sp_terminal, BsElement, BsFilterOp, MpElement, MpOp,
    SpElement, SpOp, TINY,
};
use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::inference::{
    apply_growth_policy, bs_posterior_from_forward, copy_elements_shifted,
    mp_map_from_scans, sp_posterior_from_scans, streaming, ElementBuf,
    MapEstimate, Posterior, Workspace,
};
use crate::jsonx::Json;
use crate::kalman::{
    kf_element_chain, kf_element_protos, kf_prior_element, kf_step_element,
    ks_from_forward, predict_moments, step_loglik, words_to_obs, KfElement,
    KfOp, KfProtos, KsElement, Lgssm,
};
use crate::linalg::normalize_sum;
use crate::scan::{run_scan_rev, CheckpointedScan, ScanEngine, ScanOptions};

use super::Engine;

/// Default checkpoint block length when neither the session options nor
/// the engine's scan options pin one.
pub const DEFAULT_SESSION_BLOCK: usize = 256;

/// Which element family a session streams (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SessionKind {
    /// Sum-product scan elements: filtering, fixed-lag smoothing, exact
    /// finish (plus the lazy max-product track for MAP queries).
    #[default]
    SumProduct,
    /// Bayesian filtering elements (BS-Par): `push`/`filtered`/`finish`
    /// only; fixed-lag and MAP queries return a typed error.
    Bayes,
    /// Kalman (affine-Gaussian) elements over an [`Lgssm`]:
    /// `push`/`filtered`/`finish` only, with word-encoded f64
    /// observations. Opened through
    /// [`crate::kalman::KalmanEngine::open_session`] — [`Engine`]
    /// cannot host this family (it has no Gaussian model).
    Kalman,
}

impl SessionKind {
    /// Stable snapshot/wire name.
    pub fn name(self) -> &'static str {
        match self {
            SessionKind::SumProduct => "sp",
            SessionKind::Bayes => "bs",
            SessionKind::Kalman => "kf",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn parse(s: &str) -> Option<SessionKind> {
        match s {
            "sp" => Some(SessionKind::SumProduct),
            "bs" => Some(SessionKind::Bayes),
            "kf" => Some(SessionKind::Kalman),
            _ => None,
        }
    }
}

/// Options for [`Engine::open_session`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionOptions {
    /// Checkpoint block length B. `None` inherits the engine's pinned
    /// [`ScanOptions::block`] when set, else [`DEFAULT_SESSION_BLOCK`].
    pub block: Option<usize>,
    /// Maintain the max-product scan from the first push. Off by
    /// default: the first MAP query performs an O(T) catch-up instead,
    /// and smoothing-only sessions pay nothing. Ignored for
    /// [`SessionKind::Bayes`] sessions (no max-product track).
    pub track_map: bool,
    /// Element family (default: sum-product).
    pub kind: SessionKind,
}

/// Filtering state after `step` observations: p(x_step | y_{1:step})
/// and the running log-likelihood log p(y_{1:step}).
#[derive(Debug, Clone, PartialEq)]
pub struct Filtered {
    /// Filtering marginal p(x_step | y_{1:step}): length D for the
    /// discrete families; `[mean | covariance row-major]` (length
    /// n + n²) for Kalman sessions.
    pub probs: Vec<f64>,
    /// Running log-likelihood log p(y_{1:step}). For Kalman sessions
    /// this accumulates incrementally per push and is tolerance-equal
    /// (not bit-equal) to the one-shot value; `finish` recomputes it
    /// exactly.
    pub log_likelihood: f64,
    /// Number of observations conditioned on (the absolute step is
    /// `step - 1`). Kalman sessions count complete observation rows,
    /// not raw words.
    pub step: usize,
}

/// Fixed-lag smoothing result: marginals for absolute steps
/// `start .. start + posterior.len()`, conditioned on every observation
/// pushed so far.
#[derive(Debug, Clone)]
pub struct LagSmoothed {
    /// Absolute step of the window's first marginal.
    pub start: usize,
    /// Smoothing marginals over the window.
    pub posterior: Posterior,
    /// Width of the forward suffix rescan that served the query (≤ lag
    /// + block) — the coordinator's suffix-width histogram feeds on it.
    pub rescan_width: usize,
}

/// Fixed-lag MAP decode: per-step MAP-consistent states for absolute
/// steps `start .. start + path.len()` (Eq. 40 restricted to the
/// window), plus the running joint log-maximum.
#[derive(Debug, Clone)]
pub struct LagDecoded {
    /// Absolute step of the window's first state.
    pub start: usize,
    /// MAP-consistent states over the window.
    pub path: Vec<u32>,
    /// Running joint log-maximum over the full prefix.
    pub log_prob: f64,
    /// Width of the forward suffix rescan that served the query.
    pub rescan_width: usize,
}

/// Sum-product track: the checkpointed forward scan plus the cached
/// per-symbol element prototypes.
struct SpTrack {
    scan: CheckpointedScan<SpElement, SpOp>,
    protos: Vec<SpElement>,
}

impl SpTrack {
    fn new(hmm: &Hmm, block: usize) -> Self {
        Self {
            scan: CheckpointedScan::new(SpOp { d: hmm.num_states() }, block),
            protos: sp_element_protos(hmm),
        }
    }
}

/// Lazily-enabled max-product tracking state.
struct MpTrack {
    scan: CheckpointedScan<MpElement, MpOp>,
    protos: Vec<MpElement>,
}

impl MpTrack {
    fn new(hmm: &Hmm, block: usize) -> Self {
        Self {
            scan: CheckpointedScan::new(MpOp { d: hmm.num_states() }, block),
            protos: mp_element_protos(hmm),
        }
    }
}

/// Bayesian filtering track (BS-Par element algebra).
struct BsTrack {
    scan: CheckpointedScan<BsElement, BsFilterOp>,
    protos: Vec<BsElement>,
}

impl BsTrack {
    fn new(hmm: &Hmm, block: usize) -> Self {
        Self {
            scan: CheckpointedScan::new(BsFilterOp { d: hmm.num_states() }, block),
            protos: bs_element_protos(hmm),
        }
    }
}

/// Kalman streaming track: the checkpointed forward scan over
/// [`KfElement`]s plus the word-row ingestion state. Unlike the
/// discrete tracks it owns its model (sessions of this family have no
/// HMM) and its finish scratch (the discrete [`Workspace`] stays
/// Gaussian-free).
struct KfTrack {
    model: Arc<Lgssm>,
    scan: CheckpointedScan<KfElement, KfOp>,
    protos: KfProtos,
    /// Complete observation rows ingested so far (`ys` may additionally
    /// hold a torn tail of un-decodable words).
    steps: usize,
    /// Running filter log-likelihood over the complete rows. Summed
    /// incrementally from checkpoint prefixes, so it is tolerance-equal
    /// (not bit-equal) to the one-shot value; `finish` recomputes it
    /// exactly through the shared post-pass.
    loglik: f64,
    /// Owned `finish` scratch (forward materialization / backward chain).
    fwd: Vec<KfElement>,
    bwd: Vec<KsElement>,
}

impl KfTrack {
    fn new(model: Arc<Lgssm>, block: usize) -> Self {
        Self {
            scan: CheckpointedScan::new(KfOp { n: model.state_dim() }, block),
            protos: kf_element_protos(&model),
            model,
            steps: 0,
            loglik: 0.0,
            fwd: Vec::new(),
            bwd: Vec::new(),
        }
    }

    /// Ingest every complete observation row now available in `ys`
    /// beyond the rows already consumed: decode, accumulate the
    /// incremental log-likelihood from the current prefix moments, and
    /// push the chain element (prior element at row 0 — the same
    /// constructors as the one-shot chain builder, which is what makes
    /// `finish` bit-identical to one-shot KS-Par).
    fn drain(&mut self, ys: &[u32]) {
        let wps = self.model.words_per_step();
        while (self.steps + 1) * wps <= ys.len() {
            let lo = self.steps * wps;
            let row = words_to_obs(&ys[lo..lo + wps]).expect("aligned row");
            let (pm, pc) = if self.steps == 0 {
                predict_moments(
                    &self.model,
                    self.model.prior_mean(),
                    self.model.prior_cov(),
                )
            } else {
                let p = self.scan.prefix();
                predict_moments(&self.model, &p.b, &p.c)
            };
            self.loglik += step_loglik(&self.model, &pm, &pc, &row);
            let e = if self.steps == 0 {
                kf_prior_element(&self.model, &row)
            } else {
                kf_step_element(&self.protos, &row)
            };
            self.scan.push(e);
            self.steps += 1;
        }
    }
}

/// The model a session streams against: discrete families carry the
/// HMM, the Kalman family a linear-Gaussian model.
enum ModelRef {
    Hmm(Arc<Hmm>),
    Lgssm(Arc<Lgssm>),
}

impl ModelRef {
    /// The discrete model — only reachable from sp/bs/mp code paths,
    /// which exist exactly when the session was opened over an HMM.
    fn hmm(&self) -> &Arc<Hmm> {
        match self {
            ModelRef::Hmm(h) => h,
            ModelRef::Lgssm(_) => {
                unreachable!("discrete track on a Kalman session")
            }
        }
    }
}

/// A long-lived streaming inference session (see the module docs for
/// the state diagram and cost model). Created by [`Engine::open_session`]
/// (discrete families) or [`crate::kalman::KalmanEngine::open_session`]
/// (Kalman family).
pub struct Session {
    model: ModelRef,
    scan: ScanOptions,
    ys: Vec<u32>,
    kind: SessionKind,
    /// Some iff `kind == SumProduct`.
    sp: Option<SpTrack>,
    /// Some iff `kind == Bayes`.
    bs: Option<BsTrack>,
    /// Some iff `kind == Kalman`.
    kf: Option<KfTrack>,
    mp: Option<MpTrack>,
    ws: Workspace,
}

impl Engine {
    /// Open a streaming session against this engine's model and scan
    /// options. The session pins the chunked engine and its block
    /// length, so [`Session::finish`] is bit-identical to
    /// [`Engine::run`](Engine::run) with [`Algorithm::SpPar`] (or
    /// [`Algorithm::BsPar`] for Bayes sessions) on an engine configured
    /// with [`Session::scan_options`] — in particular on *this* engine
    /// when its own options already pin the same block.
    ///
    /// # Panics
    ///
    /// If `opts.kind` is [`SessionKind::Kalman`] — that family carries a
    /// Gaussian model this engine does not have; open it through
    /// [`crate::kalman::KalmanEngine::open_session`].
    ///
    /// [`Algorithm::SpPar`]: super::Algorithm::SpPar
    /// [`Algorithm::BsPar`]: super::Algorithm::BsPar
    pub fn open_session(&self, opts: SessionOptions) -> Session {
        let block = opts
            .block
            .or(self.scan.block)
            .unwrap_or(DEFAULT_SESSION_BLOCK)
            .max(1);
        Session::new(Arc::clone(&self.hmm), self.scan, block, opts)
    }

    /// Restore a session from a [`Session::snapshot`]. Observations are
    /// replayed into a fresh element chain (O(T·D²)); the serialized
    /// block summaries skip the O(T·D³) refold. Snapshots are trusted
    /// state: shape mismatches are rejected, stale summaries are not
    /// re-verified.
    pub fn resume_session(&self, snap: &Json) -> Result<Session> {
        let (kind, block, track_map, ys) = snapshot_header(snap)?;
        if kind == SessionKind::Kalman {
            return Err(Error::invalid_request(
                "kalman session snapshots resume through \
                 kalman::KalmanEngine::resume_session — this engine has no \
                 Gaussian model",
            ));
        }
        if !ys.is_empty() {
            self.hmm.check_observations(&ys)?;
        }
        let d = self.hmm.num_states();

        let mut session = Session {
            model: ModelRef::Hmm(Arc::clone(&self.hmm)),
            scan: Session::pinned_scan(self.scan, block),
            ys,
            kind,
            sp: None,
            bs: None,
            kf: None,
            mp: None,
            ws: Workspace::default(),
        };
        match kind {
            SessionKind::SumProduct => {
                let summaries: Vec<SpElement> = snap
                    .get("sp_summaries")
                    .as_arr()
                    .ok_or_else(|| {
                        Error::invalid_request("session snapshot: 'sp_summaries'")
                    })?
                    .iter()
                    .map(sp_element_from_json)
                    .collect::<Result<_>>()?;
                let tail = match snap.get("sp_tail") {
                    Json::Null => None,
                    v => Some(sp_element_from_json(v)?),
                };
                for e in summaries.iter().chain(tail.as_ref()) {
                    check_sp_shape(e, d)?;
                }
                let elems = sp_element_chain(&self.hmm, &session.ys);
                let scan = CheckpointedScan::from_parts(
                    SpOp { d },
                    block,
                    elems,
                    summaries,
                    tail,
                )?;
                session.sp =
                    Some(SpTrack { scan, protos: sp_element_protos(&self.hmm) });
                if track_map {
                    session.ensure_mp();
                }
            }
            SessionKind::Bayes => {
                let summaries: Vec<BsElement> = snap
                    .get("bs_summaries")
                    .as_arr()
                    .ok_or_else(|| {
                        Error::invalid_request("session snapshot: 'bs_summaries'")
                    })?
                    .iter()
                    .map(bs_element_from_json)
                    .collect::<Result<_>>()?;
                let tail = match snap.get("bs_tail") {
                    Json::Null => None,
                    v => Some(bs_element_from_json(v)?),
                };
                for e in summaries.iter().chain(tail.as_ref()) {
                    check_bs_shape(e, d)?;
                }
                let elems = bs_element_chain(&self.hmm, &session.ys);
                let scan = CheckpointedScan::from_parts(
                    BsFilterOp { d },
                    block,
                    elems,
                    summaries,
                    tail,
                )?;
                session.bs =
                    Some(BsTrack { scan, protos: bs_element_protos(&self.hmm) });
            }
            SessionKind::Kalman => unreachable!("rejected above"),
        }
        Ok(session)
    }
}

/// Parse the `version`/`kind`/`block`/`track_map`/`ys` header shared by
/// every snapshot family ([`Engine::resume_session`] and
/// `Session::resume_kalman`).
fn snapshot_header(snap: &Json) -> Result<(SessionKind, usize, bool, Vec<u32>)> {
    // Version 1 wrote decimal number arrays; version 2 writes the
    // packed hex payloads of `elements::serde`. The payload parsers
    // accept both encodings, so both versions resume here.
    if !matches!(snap.get("version").as_usize(), Some(1 | 2)) {
        return Err(Error::invalid_request(
            "session snapshot: unsupported or missing version \
             (expected 1 or 2)",
        ));
    }
    let kind = match snap.get("kind") {
        Json::Null => SessionKind::SumProduct, // pre-kind snapshots
        v => v.as_str().and_then(SessionKind::parse).ok_or_else(|| {
            Error::invalid_request("session snapshot: unknown 'kind'")
        })?,
    };
    let block = snap
        .get("block")
        .as_usize()
        .ok_or_else(|| Error::invalid_request("session snapshot: 'block'"))?
        .max(1);
    let track_map = snap.get("track_map").as_bool().unwrap_or(false);
    let ys: Vec<u32> = match snap.get("ys") {
        Json::Null => {
            return Err(Error::invalid_request("session snapshot: 'ys'"))
        }
        v => obs_from_json(v)?,
    };
    Ok((kind, block, track_map, ys))
}

impl Session {
    fn new(hmm: Arc<Hmm>, scan: ScanOptions, block: usize, opts: SessionOptions) -> Self {
        let (sp, bs, mp) = match opts.kind {
            SessionKind::SumProduct => (
                Some(SpTrack::new(&hmm, block)),
                None,
                opts.track_map.then(|| MpTrack::new(&hmm, block)),
            ),
            SessionKind::Bayes => (None, Some(BsTrack::new(&hmm, block)), None),
            SessionKind::Kalman => panic!(
                "kalman sessions are opened through \
                 kalman::KalmanEngine::open_session"
            ),
        };
        Self {
            scan: Self::pinned_scan(scan, block),
            model: ModelRef::Hmm(hmm),
            ys: Vec::new(),
            kind: opts.kind,
            sp,
            bs,
            kf: None,
            mp,
            ws: Workspace::default(),
        }
    }

    /// Open a Kalman streaming session. Crate-internal: callers go
    /// through [`crate::kalman::KalmanEngine::open_session`], which
    /// supplies the Gaussian model and scan options.
    pub(crate) fn open_kalman(
        model: Arc<Lgssm>,
        scan: ScanOptions,
        block: usize,
    ) -> Session {
        Session {
            scan: Self::pinned_scan(scan, block),
            model: ModelRef::Lgssm(Arc::clone(&model)),
            ys: Vec::new(),
            kind: SessionKind::Kalman,
            sp: None,
            bs: None,
            kf: Some(KfTrack::new(model, block)),
            mp: None,
            ws: Workspace::default(),
        }
    }

    /// Restore a Kalman session from a [`Session::snapshot`].
    /// Crate-internal: callers go through
    /// [`crate::kalman::KalmanEngine::resume_session`]. Mirrors
    /// [`Engine::resume_session`]: the word stream is replayed into a
    /// fresh element chain, the serialized block summaries skip the
    /// refold, and a trailing torn row (if any) stays buffered.
    pub(crate) fn resume_kalman(
        model: Arc<Lgssm>,
        scan: ScanOptions,
        snap: &Json,
    ) -> Result<Session> {
        let (kind, block, _track_map, ys) = snapshot_header(snap)?;
        if kind != SessionKind::Kalman {
            return Err(Error::invalid_request(format!(
                "snapshot kind '{}' is not a kalman session — resume it \
                 through engine::Engine::resume_session",
                kind.name()
            )));
        }
        let n = model.state_dim();
        let summaries: Vec<KfElement> = snap
            .get("kf_summaries")
            .as_arr()
            .ok_or_else(|| {
                Error::invalid_request("session snapshot: 'kf_summaries'")
            })?
            .iter()
            .map(kf_element_from_json)
            .collect::<Result<_>>()?;
        let tail = match snap.get("kf_tail") {
            Json::Null => None,
            v => Some(kf_element_from_json(v)?),
        };
        for e in summaries.iter().chain(tail.as_ref()) {
            check_kf_shape(e, n)?;
        }
        let loglik = match snap.get("kf_loglik") {
            // Version 2: one hex-packed f64 (exact restore).
            Json::Str(s) => {
                let v = f64s_from_hex(s)?;
                if v.len() != 1 {
                    return Err(Error::invalid_request(
                        "session snapshot: 'kf_loglik' must hold exactly \
                         one value",
                    ));
                }
                v[0]
            }
            Json::Num(v) => *v,
            _ => {
                return Err(Error::invalid_request(
                    "session snapshot: 'kf_loglik'",
                ))
            }
        };
        let wps = model.words_per_step();
        let steps = ys.len() / wps;
        let obs = words_to_obs(&ys[..steps * wps])?;
        let elems = kf_element_chain(&model, &obs);
        let scan_cp = CheckpointedScan::from_parts(
            KfOp { n },
            block,
            elems,
            summaries,
            tail,
        )?;
        Ok(Session {
            scan: Self::pinned_scan(scan, block),
            ys,
            kind: SessionKind::Kalman,
            sp: None,
            bs: None,
            kf: Some(KfTrack {
                scan: scan_cp,
                protos: kf_element_protos(&model),
                model: Arc::clone(&model),
                steps,
                loglik,
                fwd: Vec::new(),
                bwd: Vec::new(),
            }),
            model: ModelRef::Lgssm(model),
            mp: None,
            ws: Workspace::default(),
        })
    }

    /// The engine's options with the session's block pinned and the
    /// chunked schedule forced (checkpoints are chunked-scan state).
    fn pinned_scan(mut scan: ScanOptions, block: usize) -> ScanOptions {
        scan.engine = ScanEngine::Chunked;
        scan.block = Some(block);
        scan
    }

    /// Number of observations pushed so far (raw u32 words for Kalman
    /// sessions — divide by `Lgssm::words_per_step` for rows).
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    /// Whether nothing has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    /// The element family this session streams.
    pub fn kind(&self) -> SessionKind {
        self.kind
    }

    /// Checkpoint block length B.
    pub fn block(&self) -> usize {
        match (&self.sp, &self.bs, &self.kf) {
            (Some(sp), _, _) => sp.scan.block(),
            (_, Some(bs), _) => bs.scan.block(),
            (_, _, Some(kf)) => kf.scan.block(),
            _ => unreachable!("session has exactly one primary track"),
        }
    }

    /// The scan options [`finish`](Self::finish) runs under — configure
    /// an [`Engine`] with exactly these to reproduce its output
    /// bit-for-bit via [`Engine::run`].
    pub fn scan_options(&self) -> ScanOptions {
        self.scan
    }

    /// Everything pushed so far (the encoded word stream for Kalman
    /// sessions).
    pub fn observations(&self) -> &[u32] {
        &self.ys
    }

    /// Validate an append without ingesting it — exactly what
    /// [`push`](Self::push) would reject, checked ahead of time. The
    /// coordinator calls this before the chunk reaches the durable
    /// append-ahead log, so an invalid chunk can never become a
    /// replayable log record.
    ///
    /// For discrete families this is the model's symbol-range check; for
    /// Kalman sessions the words are joined with any buffered torn-row
    /// tail and every row the append *completes* is checked finite (a
    /// torn f64 half cannot be judged until its row closes).
    pub fn validate_append(&self, obs: &[u32]) -> Result<()> {
        if obs.is_empty() {
            return Ok(());
        }
        if let Some(kf) = &self.kf {
            let wps = kf.model.words_per_step();
            let mut pending = self.ys[kf.steps * wps..].to_vec();
            pending.extend_from_slice(obs);
            let complete = (pending.len() / wps) * wps;
            let rows =
                words_to_obs(&pending[..complete]).expect("even word count");
            if let Some(v) = rows.iter().find(|v| !v.is_finite()) {
                return Err(Error::invalid_request(format!(
                    "non-finite observation value {v} in append"
                )));
            }
            return Ok(());
        }
        self.model.hmm().check_observations(obs)
    }

    /// Ingest observations: O(k·D³) fold work — per observation, one
    /// retained chain element plus one scratch-carried fold step (no
    /// transient allocation; see `scan::CheckpointedScan::push`).
    /// Rejects out-of-range symbols atomically (no partial append); an
    /// empty slice is a no-op.
    pub fn push(&mut self, obs: &[u32]) -> Result<()> {
        if obs.is_empty() {
            return Ok(());
        }
        self.validate_append(obs)?;
        if let Some(kf) = &mut self.kf {
            self.ys.extend_from_slice(obs);
            kf.drain(&self.ys);
            return Ok(());
        }
        for &y in obs {
            let k = self.ys.len();
            if let Some(sp) = &mut self.sp {
                sp.scan.push(element_at(
                    k,
                    y,
                    || sp_prior_element(self.model.hmm(), y),
                    &sp.protos,
                ));
            }
            if let Some(bs) = &mut self.bs {
                bs.scan.push(element_at(
                    k,
                    y,
                    || bs_prior_element(self.model.hmm(), y),
                    &bs.protos,
                ));
            }
            if let Some(mp) = &mut self.mp {
                mp.scan.push(element_at(
                    k,
                    y,
                    || mp_prior_element(self.model.hmm(), y),
                    &mp.protos,
                ));
            }
            self.ys.push(y);
        }
        Ok(())
    }

    /// The current filtering marginal p(x_t | y_{1:t}) and running
    /// log-likelihood — one combine off the checkpoint state, for either
    /// element family.
    pub fn filtered(&self) -> Result<Filtered> {
        if let Some(kf) = &self.kf {
            // Complete rows only: a buffered torn tail is invisible to
            // queries until its row closes.
            if kf.steps == 0 {
                return Err(Error::invalid_request(
                    "session has no complete observation row yet",
                ));
            }
            let p = kf.scan.prefix();
            let n = kf.model.state_dim();
            let mut probs = Vec::with_capacity(n + n * n);
            probs.extend_from_slice(&p.b);
            probs.extend_from_slice(p.c.data());
            return Ok(Filtered {
                probs,
                log_likelihood: kf.loglik,
                step: kf.steps,
            });
        }
        self.check_nonempty()?;
        let step = self.ys.len();
        match (&self.sp, &self.bs) {
            (Some(sp), _) => {
                let prefix = sp.scan.prefix();
                let mut probs: Vec<f64> = prefix.mat.row(0).to_vec();
                let sum = normalize_sum(&mut probs);
                let log_likelihood =
                    prefix.log_scale + sum.max(f64::MIN_POSITIVE).ln();
                Ok(Filtered { probs, log_likelihood, step })
            }
            (_, Some(bs)) => {
                // Row 0 of f is p(x_t | y_{1:t}) once the prior element
                // is absorbed; ĝ is constant in x_0 = rescaled p(y_{1:t}).
                let prefix = bs.scan.prefix();
                let mut probs: Vec<f64> = prefix.f.row(0).to_vec();
                normalize_sum(&mut probs);
                let log_likelihood =
                    prefix.log_scale + prefix.g[0].max(TINY).ln();
                Ok(Filtered { probs, log_likelihood, step })
            }
            _ => unreachable!("session has exactly one primary track"),
        }
    }

    /// Fixed-lag smoothing: exact marginals p(x_k | y_{1:t}) for the
    /// last `lag` steps (fewer when the session is younger), via a
    /// forward suffix rescan from the covering checkpoint and a parallel
    /// backward scan over the window only — O((lag + B)·D³).
    /// Sum-product sessions only.
    pub fn smoothed_lag(&mut self, lag: usize) -> Result<LagSmoothed> {
        self.check_nonempty()?;
        if self.kf.is_some() {
            return Err(kalman_unsupported("smoothed_lag"));
        }
        let Some(sp) = self.sp.as_ref() else {
            return Err(bayes_unsupported("smoothed_lag"));
        };
        let d = self.model.hmm().num_states();
        let sb = &mut self.ws.stream;
        let win = lag_window(
            &sp.scan,
            &sp.protos,
            sp_terminal(d),
            &self.ys,
            lag,
            self.scan,
            &mut sb.sp_fwd_win,
            &mut sb.sp_bwd_win,
            &SpOp { d },
        );
        let posterior = streaming::sp_window_posterior(
            d,
            win.start,
            win.fwd_offset,
            &sb.sp_fwd_win,
            &sb.sp_bwd_win,
        );
        Ok(LagSmoothed {
            start: win.start,
            posterior,
            rescan_width: win.rescan_width,
        })
    }

    /// Fixed-lag MAP decode over the last `lag` steps (the streaming
    /// max-product analogue of [`smoothed_lag`](Self::smoothed_lag)).
    /// The first call on a session opened without
    /// [`SessionOptions::track_map`] replays the history into the
    /// max-product scan (O(T); incremental afterwards). Sum-product
    /// sessions only.
    pub fn map_lag(&mut self, lag: usize) -> Result<LagDecoded> {
        self.check_nonempty()?;
        if self.kf.is_some() {
            return Err(kalman_unsupported("map_lag"));
        }
        if self.sp.is_none() {
            return Err(bayes_unsupported("map_lag"));
        }
        self.ensure_mp();
        let d = self.model.hmm().num_states();
        let mp = self.mp.as_ref().expect("ensure_mp");
        let sb = &mut self.ws.stream;
        let win = lag_window(
            &mp.scan,
            &mp.protos,
            mp_terminal(d),
            &self.ys,
            lag,
            self.scan,
            &mut sb.mp_fwd_win,
            &mut sb.mp_bwd_win,
            &MpOp { d },
        );
        let (path, log_prob) = streaming::mp_window_path(
            d,
            win.start,
            win.fwd_offset,
            &sb.mp_fwd_win,
            &sb.mp_bwd_win,
        );
        Ok(LagDecoded {
            start: win.start,
            path,
            log_prob,
            rescan_width: win.rescan_width,
        })
    }

    /// The exact full-sequence smoothing posterior — bit-identical to
    /// `Engine::run(Algorithm::SpPar, ..)` (sum-product sessions) or
    /// `Engine::run(Algorithm::BsPar, ..)` (Bayes sessions) under
    /// [`scan_options`](Self::scan_options). The forward scan comes from
    /// the checkpoints (phase 3 only — half the combines of a cold run);
    /// the backward pass is unavoidable O(T). The session stays usable:
    /// more pushes may follow.
    pub fn finish(&mut self) -> Result<Posterior> {
        self.check_nonempty()?;
        if let Some(kf) = &mut self.kf {
            // KS-Par replay: checkpointed forward materialization
            // (bit-identical to the one-shot forward scan under the
            // pinned block), then the shared smoothing post-pass — which
            // also recomputes the log-likelihood exactly.
            if kf.steps == 0 {
                return Err(Error::invalid_request(
                    "session has no complete observation row yet",
                ));
            }
            let wps = kf.model.words_per_step();
            if self.ys.len() != kf.steps * wps {
                return Err(Error::invalid_request(
                    "cannot finish with a torn observation row pending \
                     (incomplete f64 words buffered)",
                ));
            }
            let obs = words_to_obs(&self.ys)?;
            kf.scan.materialize_into(&mut kf.fwd, self.scan);
            let KfTrack { model, fwd, bwd, .. } = kf;
            return Ok(ks_from_forward(model, &obs, fwd, self.scan, bwd));
        }
        let d = self.model.hmm().num_states();
        if let Some(bs) = &self.bs {
            // BS-Par replay: checkpointed forward materialization, then
            // the shared RTS backward pass.
            bs.scan.materialize_into(&mut self.ws.bs.elems, self.scan);
            return Ok(bs_posterior_from_forward(
                self.model.hmm(),
                &self.ws.bs.elems,
                self.scan,
                &mut self.ws.bs.rts,
            ));
        }
        let sp = self.sp.as_ref().expect("sp track");
        materialize_full(
            &sp.scan,
            sp_terminal(d),
            self.scan,
            &mut self.ws.sp.fwd,
            &mut self.ws.sp.bwd,
            &SpOp { d },
        );
        Ok(sp_posterior_from_scans(d, &self.ws.sp.fwd, &self.ws.sp.bwd))
    }

    /// The exact full-sequence MAP estimate — bit-identical to
    /// `Engine::run(Algorithm::MpPar, ..)` under
    /// [`scan_options`](Self::scan_options). Sum-product sessions only.
    pub fn finish_map(&mut self) -> Result<MapEstimate> {
        self.check_nonempty()?;
        if self.kf.is_some() {
            return Err(kalman_unsupported("finish_map"));
        }
        if self.sp.is_none() {
            return Err(bayes_unsupported("finish_map"));
        }
        self.ensure_mp();
        let d = self.model.hmm().num_states();
        let mp = self.mp.as_ref().expect("ensure_mp");
        materialize_full(
            &mp.scan,
            mp_terminal(d),
            self.scan,
            &mut self.ws.mp.fwd,
            &mut self.ws.mp.bwd,
            &MpOp { d },
        );
        Ok(mp_map_from_scans(d, &self.ws.mp.fwd, &self.ws.mp.bwd))
    }

    /// Export the session as JSON: observations, options, and the block
    /// summaries of the primary track (exact element serialization — see
    /// `elements::serde`), so [`Engine::resume_session`] restores
    /// without refolding. The max-product track, when enabled, is
    /// rebuilt by replay on resume. This is also the eviction payload of
    /// the coordinator's session store.
    pub fn snapshot(&self) -> Json {
        let mut obj = BTreeMap::new();
        // Version 2: observations and element payloads use the packed
        // hex encodings of `elements::serde` (~2× smaller spill logs);
        // `resume_session` still accepts version-1 decimal snapshots.
        obj.insert("version".to_string(), Json::Num(2.0));
        obj.insert("kind".to_string(), Json::Str(self.kind.name().to_string()));
        obj.insert("block".to_string(), Json::Num(self.block() as f64));
        obj.insert("track_map".to_string(), Json::Bool(self.mp.is_some()));
        obj.insert("ys".to_string(), obs_to_json(&self.ys));
        if let Some(kf) = &self.kf {
            obj.insert(
                "kf_summaries".to_string(),
                Json::Arr(
                    kf.scan.summaries().iter().map(kf_element_to_json).collect(),
                ),
            );
            obj.insert(
                "kf_tail".to_string(),
                kf.scan.tail_acc().map_or(Json::Null, kf_element_to_json),
            );
            // Exact (hex) so a restored session's `filtered` is
            // bit-identical to the never-snapshotted one.
            obj.insert(
                "kf_loglik".to_string(),
                Json::Str(f64s_to_hex(&[kf.loglik])),
            );
            return Json::Obj(obj);
        }
        match (&self.sp, &self.bs) {
            (Some(sp), _) => {
                obj.insert(
                    "sp_summaries".to_string(),
                    Json::Arr(
                        sp.scan.summaries().iter().map(sp_element_to_json).collect(),
                    ),
                );
                obj.insert(
                    "sp_tail".to_string(),
                    sp.scan.tail_acc().map_or(Json::Null, sp_element_to_json),
                );
            }
            (_, Some(bs)) => {
                obj.insert(
                    "bs_summaries".to_string(),
                    Json::Arr(
                        bs.scan.summaries().iter().map(bs_element_to_json).collect(),
                    ),
                );
                obj.insert(
                    "bs_tail".to_string(),
                    bs.scan.tail_acc().map_or(Json::Null, bs_element_to_json),
                );
            }
            _ => unreachable!("session has exactly one primary track"),
        }
        Json::Obj(obj)
    }

    /// Build the max-product scan by replaying the stored observations
    /// (no-op once present).
    fn ensure_mp(&mut self) {
        if self.mp.is_some() {
            return;
        }
        let mut track = MpTrack::new(self.model.hmm(), self.block());
        for (k, &y) in self.ys.iter().enumerate() {
            track.scan.push(element_at(
                k,
                y,
                || mp_prior_element(self.model.hmm(), y),
                &track.protos,
            ));
        }
        self.mp = Some(track);
    }

    fn check_nonempty(&self) -> Result<()> {
        if self.ys.is_empty() {
            return Err(Error::invalid_request(
                "session has no observations yet",
            ));
        }
        Ok(())
    }
}

/// The typed rejection for queries the Bayesian element family does not
/// serve (fixed-lag windows and MAP tracks need the potential-based
/// elements).
fn bayes_unsupported(what: &str) -> Error {
    Error::invalid_request(format!(
        "bayes (BS-Par) sessions support push/filtered/finish/snapshot only: \
         {what} is not implemented for the Bayesian element family"
    ))
}

/// The typed rejection for queries the Kalman element family does not
/// serve (fixed-lag windows and MAP decoding are discrete-track
/// features).
fn kalman_unsupported(what: &str) -> Error {
    Error::invalid_request(format!(
        "kalman sessions support push/filtered/finish/snapshot only: \
         {what} is not implemented for the Gaussian element family"
    ))
}

/// The chain element for absolute step `k`: the prior element at k = 0,
/// a prototype clone afterwards — the single definition every append
/// path (sp, bs, mp, replay) shares, since the bit-identity contract
/// depends on them agreeing with the one-shot chain builders.
fn element_at<E: Clone>(
    k: usize,
    y: u32,
    prior: impl FnOnce() -> E,
    protos: &[E],
) -> E {
    if k == 0 {
        prior()
    } else {
        protos[y as usize].clone()
    }
}

/// Window geometry produced by [`lag_window`].
struct Window {
    start: usize,
    fwd_offset: usize,
    rescan_width: usize,
}

/// The fixed-lag window pipeline shared by `smoothed_lag` and `map_lag`:
/// forward suffix rescan from the covering checkpoint into `fwd_win`,
/// backward suffix scan over the window chain into `bwd_win`. The sp/mp
/// paths must not diverge — only the element family and finalizer differ.
#[allow(clippy::too_many_arguments)]
fn lag_window<E, Op>(
    scan: &CheckpointedScan<E, Op>,
    protos: &[E],
    terminal: E,
    ys: &[u32],
    lag: usize,
    opts: ScanOptions,
    fwd_win: &mut Vec<E>,
    bwd_win: &mut Vec<E>,
    op: &Op,
) -> Window
where
    E: ElementBuf + Send + Sync,
    Op: crate::scan::AssocOp<E>,
{
    let t = ys.len();
    let start = t.saturating_sub(lag.max(1));
    let from = (start / scan.block()) * scan.block();

    apply_growth_policy(fwd_win, t - from);
    let fwd_offset = scan.suffix_into(start, fwd_win);
    let rescan_width = fwd_win.len();

    apply_growth_policy(bwd_win, t - start);
    streaming::window_chain_into(protos, &ys[start + 1..], terminal, bwd_win);
    run_scan_rev(op, bwd_win.as_mut_slice(), opts);

    Window { start, fwd_offset, rescan_width }
}

/// The exact-finish pipeline shared by `finish` and `finish_map`:
/// checkpointed forward materialization (phase 3 only) plus the full
/// backward scan — bit-identical to the corresponding `*_par_ws` run.
fn materialize_full<E, Op>(
    scan: &CheckpointedScan<E, Op>,
    terminal: E,
    opts: ScanOptions,
    fwd: &mut Vec<E>,
    bwd: &mut Vec<E>,
    op: &Op,
) where
    E: ElementBuf + Send + Sync,
    Op: crate::scan::AssocOp<E>,
{
    scan.materialize_into(fwd, opts);
    copy_elements_shifted(scan.elems(), terminal, bwd);
    run_scan_rev(op, bwd.as_mut_slice(), opts);
}

//! The algorithm taxonomy — one enum for every method the paper
//! benchmarks (§VI), the single source of truth for entry-point names,
//! task families and seq/par pairings.
//!
//! Everything else derives from this enum: the coordinator's task-level
//! `Algo` (`coordinator::request`), the router's artifact entry strings,
//! the figure benches' method names, and the engine dispatch itself.

use crate::jsonx::Json;

/// Every inference method in the system, in the paper's order.
///
/// | variant | paper name | section |
/// |---------|------------|---------|
/// | [`SpSeq`](Algorithm::SpSeq) | SP-Seq | Algorithm 1 + Eq. 22 |
/// | [`SpPar`](Algorithm::SpPar) | SP-Par | Algorithm 3 |
/// | [`BsSeq`](Algorithm::BsSeq) | BS-Seq | filter + RTS smoother |
/// | [`BsPar`](Algorithm::BsPar) | BS-Par | Ref. [30] discrete analogue |
/// | [`Viterbi`](Algorithm::Viterbi) | Viterbi | Algorithm 4 |
/// | [`MpSeq`](Algorithm::MpSeq) | MP-Seq | Lemma 3 + Theorem 4 |
/// | [`MpPar`](Algorithm::MpPar) | MP-Par | Algorithm 5 |
/// | [`MpPathPar`](Algorithm::MpPathPar) | MP-Path-Par | §IV-B |
/// | [`BaumWelch`](Algorithm::BaumWelch) | Baum-Welch | §V-C |
/// | [`KfSeq`](Algorithm::KfSeq) | KF-Seq | 1905.13002, classical KF |
/// | [`KfPar`](Algorithm::KfPar) | KF-Par | 1905.13002 §3 |
/// | [`KsSeq`](Algorithm::KsSeq) | KS-Seq | 1905.13002, classical RTS |
/// | [`KsPar`](Algorithm::KsPar) | KS-Par | 1905.13002 §4 |
///
/// The last four are the affine-Gaussian (Kalman) tier of the sibling
/// paper *Temporal Parallelization of Bayesian Smoothers*
/// (arXiv:1905.13002); they run on [`crate::kalman::Lgssm`] models
/// through [`crate::kalman::KalmanEngine`], not the discrete-HMM
/// [`crate::engine::Engine`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Classical sum-product smoother (forward–backward).
    SpSeq,
    /// Parallel-scan sum-product smoother.
    SpPar,
    /// Sequential Bayesian smoother (filter + RTS).
    BsSeq,
    /// Parallel Bayesian smoother.
    BsPar,
    /// Classical Viterbi MAP decoder.
    Viterbi,
    /// Sequential max-product MAP decoder.
    MpSeq,
    /// Parallel-scan max-product MAP decoder.
    MpPar,
    /// Path-based parallel MAP decoder (Definition 4).
    MpPathPar,
    /// Baum–Welch EM parameter estimation.
    BaumWelch,
    /// Classical sequential Kalman filter.
    KfSeq,
    /// Parallel-scan Kalman filter.
    KfPar,
    /// Classical Rauch–Tung–Striebel smoother.
    KsSeq,
    /// Parallel-scan Kalman (RTS) smoother.
    KsPar,
}

/// What an algorithm produces — the output-shape family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Smoothing marginals p(x_k | y_{1:T}) → `Posterior`.
    Smoothing,
    /// MAP state sequence → `MapEstimate`.
    MapDecoding,
    /// Parameter estimation → `BaumWelchResult`.
    Training,
    /// Gaussian filtered/smoothed moments → `Posterior` with
    /// `d = n + n²`, row k = `[mean | covariance row-major]`.
    Gaussian,
}

impl Algorithm {
    /// All thirteen methods: the paper's nine in its order, then the
    /// Kalman tier of arXiv:1905.13002.
    pub const ALL: [Algorithm; 13] = [
        Algorithm::SpSeq,
        Algorithm::SpPar,
        Algorithm::BsSeq,
        Algorithm::BsPar,
        Algorithm::Viterbi,
        Algorithm::MpSeq,
        Algorithm::MpPar,
        Algorithm::MpPathPar,
        Algorithm::BaumWelch,
        Algorithm::KfSeq,
        Algorithm::KfPar,
        Algorithm::KsSeq,
        Algorithm::KsPar,
    ];

    /// Stable snake_case identifier — also the AOT artifact entry name
    /// (`python/compile/aot.py` compiles `sp_par`, `mp_par`, … cores).
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::SpSeq => "sp_seq",
            Algorithm::SpPar => "sp_par",
            Algorithm::BsSeq => "bs_seq",
            Algorithm::BsPar => "bs_par",
            Algorithm::Viterbi => "viterbi",
            Algorithm::MpSeq => "mp_seq",
            Algorithm::MpPar => "mp_par",
            Algorithm::MpPathPar => "mp_path_par",
            Algorithm::BaumWelch => "baum_welch",
            Algorithm::KfSeq => "kf_seq",
            Algorithm::KfPar => "kf_par",
            Algorithm::KsSeq => "ks_seq",
            Algorithm::KsPar => "ks_par",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(s: &str) -> Option<Algorithm> {
        Algorithm::ALL.into_iter().find(|a| a.name() == s)
    }

    /// The paper's display name (figure legends, Table I).
    pub fn paper_name(self) -> &'static str {
        match self {
            Algorithm::SpSeq => "SP-Seq",
            Algorithm::SpPar => "SP-Par",
            Algorithm::BsSeq => "BS-Seq",
            Algorithm::BsPar => "BS-Par",
            Algorithm::Viterbi => "Viterbi",
            Algorithm::MpSeq => "MP-Seq",
            Algorithm::MpPar => "MP-Par",
            Algorithm::MpPathPar => "MP-Path-Par",
            Algorithm::BaumWelch => "Baum-Welch",
            Algorithm::KfSeq => "KF-Seq",
            Algorithm::KfPar => "KF-Par",
            Algorithm::KsSeq => "KS-Seq",
            Algorithm::KsPar => "KS-Par",
        }
    }

    /// Inverse of [`paper_name`](Self::paper_name).
    pub fn from_paper_name(s: &str) -> Option<Algorithm> {
        Algorithm::ALL.into_iter().find(|a| a.paper_name() == s)
    }

    /// Output-shape family.
    pub fn task(self) -> Task {
        match self {
            Algorithm::SpSeq | Algorithm::SpPar | Algorithm::BsSeq
            | Algorithm::BsPar => Task::Smoothing,
            Algorithm::Viterbi | Algorithm::MpSeq | Algorithm::MpPar
            | Algorithm::MpPathPar => Task::MapDecoding,
            Algorithm::BaumWelch => Task::Training,
            Algorithm::KfSeq | Algorithm::KfPar | Algorithm::KsSeq
            | Algorithm::KsPar => Task::Gaussian,
        }
    }

    /// Whether `engine::Session` can serve this algorithm's task
    /// incrementally: the parallel-scan formulations whose element
    /// algebra is checkpointable — `SpPar` behind
    /// `Session::filtered`/`smoothed_lag`/`finish`, `MpPar` behind
    /// `map_lag`/`finish_map`, `BsPar` behind `SessionKind::Bayes`
    /// sessions (`filtered`/`finish`; fixed-lag queries stay
    /// unsupported for that family), and `KfPar`/`KsPar` behind
    /// `SessionKind::Kalman` sessions (`filtered` serves the KF-Par
    /// moments, `finish` the KS-Par smoothing pass).
    pub fn supports_streaming(self) -> bool {
        matches!(
            self,
            Algorithm::SpPar | Algorithm::MpPar | Algorithm::BsPar
                | Algorithm::KfPar | Algorithm::KsPar
        )
    }

    /// Whether this is a parallel-scan formulation (O(log T) span).
    pub fn is_parallel(self) -> bool {
        matches!(
            self,
            Algorithm::SpPar | Algorithm::BsPar | Algorithm::MpPar
                | Algorithm::MpPathPar | Algorithm::KfPar | Algorithm::KsPar
        )
    }

    /// The sequential counterpart (identity for seq methods and training).
    pub fn seq_variant(self) -> Algorithm {
        match self {
            Algorithm::SpPar => Algorithm::SpSeq,
            Algorithm::BsPar => Algorithm::BsSeq,
            Algorithm::MpPar => Algorithm::MpSeq,
            Algorithm::MpPathPar => Algorithm::Viterbi,
            Algorithm::KfPar => Algorithm::KfSeq,
            Algorithm::KsPar => Algorithm::KsSeq,
            other => other,
        }
    }

    /// The parallel counterpart (identity for par methods and training).
    pub fn par_variant(self) -> Algorithm {
        match self {
            Algorithm::SpSeq => Algorithm::SpPar,
            Algorithm::BsSeq => Algorithm::BsPar,
            Algorithm::MpSeq | Algorithm::Viterbi => Algorithm::MpPar,
            Algorithm::KfSeq => Algorithm::KfPar,
            Algorithm::KsSeq => Algorithm::KsPar,
            other => other,
        }
    }

    /// Block-artifact family prefix for the §V-B sharded plans
    /// (`{family}_block_fold_first`, …); `None` for training and for
    /// the Kalman tier (no AOT artifacts compiled for it yet).
    pub fn artifact_family(self) -> Option<&'static str> {
        match self {
            Algorithm::SpSeq | Algorithm::SpPar => Some("sp"),
            Algorithm::BsSeq | Algorithm::BsPar => Some("bs"),
            Algorithm::Viterbi | Algorithm::MpSeq | Algorithm::MpPar
            | Algorithm::MpPathPar => Some("mp"),
            Algorithm::BaumWelch | Algorithm::KfSeq | Algorithm::KfPar
            | Algorithm::KsSeq | Algorithm::KsPar => None,
        }
    }

    /// jsonx serialization (the stable snake_case name).
    pub fn to_json(self) -> Json {
        Json::Str(self.name().to_string())
    }

    /// Inverse of [`to_json`](Self::to_json).
    pub fn from_json(v: &Json) -> Option<Algorithm> {
        v.as_str().and_then(Algorithm::from_name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_thirteen_methods() {
        assert_eq!(Algorithm::ALL.len(), 13);
        // Names are unique.
        for (i, a) in Algorithm::ALL.into_iter().enumerate() {
            for b in &Algorithm::ALL[i + 1..] {
                assert_ne!(a.name(), b.name());
                assert_ne!(a.paper_name(), b.paper_name());
            }
        }
    }

    #[test]
    fn name_round_trips_exhaustively() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::from_name(a.name()), Some(a));
            assert_eq!(Algorithm::from_paper_name(a.paper_name()), Some(a));
            assert_eq!(Algorithm::from_json(&a.to_json()), Some(a));
        }
        assert_eq!(Algorithm::from_name("nope"), None);
        assert_eq!(Algorithm::from_json(&Json::Num(3.0)), None);
    }

    #[test]
    fn streaming_flag_is_a_parallel_subset() {
        for a in Algorithm::ALL {
            if a.supports_streaming() {
                assert!(a.is_parallel(), "{} streams but is not parallel", a.name());
                assert_ne!(a.task(), Task::Training);
            }
        }
        assert!(Algorithm::SpPar.supports_streaming());
        assert!(Algorithm::MpPar.supports_streaming());
        assert!(Algorithm::BsPar.supports_streaming());
        assert!(Algorithm::KfPar.supports_streaming());
        assert!(Algorithm::KsPar.supports_streaming());
        assert!(!Algorithm::SpSeq.supports_streaming());
        assert!(!Algorithm::BsSeq.supports_streaming());
        assert!(!Algorithm::BaumWelch.supports_streaming());
        assert!(!Algorithm::KfSeq.supports_streaming());
        assert!(!Algorithm::KsSeq.supports_streaming());
    }

    #[test]
    fn seq_par_pairing() {
        assert_eq!(Algorithm::SpSeq.par_variant(), Algorithm::SpPar);
        assert_eq!(Algorithm::SpPar.seq_variant(), Algorithm::SpSeq);
        assert_eq!(Algorithm::Viterbi.par_variant(), Algorithm::MpPar);
        assert_eq!(Algorithm::MpPathPar.seq_variant(), Algorithm::Viterbi);
        assert_eq!(Algorithm::BaumWelch.seq_variant(), Algorithm::BaumWelch);
        assert_eq!(Algorithm::KfSeq.par_variant(), Algorithm::KfPar);
        assert_eq!(Algorithm::KfPar.seq_variant(), Algorithm::KfSeq);
        assert_eq!(Algorithm::KsSeq.par_variant(), Algorithm::KsPar);
        assert_eq!(Algorithm::KsPar.seq_variant(), Algorithm::KsSeq);
        for a in Algorithm::ALL {
            // Variant maps preserve the task family.
            assert_eq!(a.task(), a.seq_variant().task());
            assert_eq!(a.task(), a.par_variant().task());
            // par_variant is parallel (or training), seq_variant is not.
            if a.task() != Task::Training {
                assert!(a.par_variant().is_parallel());
                assert!(!a.seq_variant().is_parallel());
            }
        }
    }

    #[test]
    fn entry_names_match_aot_contract() {
        // The artifact entries python/compile/aot.py emits.
        assert_eq!(Algorithm::SpPar.name(), "sp_par");
        assert_eq!(Algorithm::MpPar.name(), "mp_par");
        assert_eq!(Algorithm::BsPar.name(), "bs_par");
        assert_eq!(Algorithm::Viterbi.name(), "viterbi");
        assert_eq!(Algorithm::SpPar.artifact_family(), Some("sp"));
        assert_eq!(Algorithm::BaumWelch.artifact_family(), None);
    }
}

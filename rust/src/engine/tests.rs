//! Engine test suite: equivalence of every [`Algorithm`] against its
//! free function (the acceptance bar for the unified API), workspace
//! reuse determinism, batch semantics, and the XLA backend decode path
//! (via a stub executor — no PJRT needed).

use std::path::PathBuf;
use std::sync::Arc;

use crate::hmm::{gilbert_elliott, sample, GeParams};
use crate::inference::{
    self, BaumWelchOptions, EStepBackend, MapEstimate, Posterior,
};
use crate::rng::Xoshiro256StarStar;
use crate::runtime::{ArtifactExec, Manifest, Value};
use crate::scan::ScanOptions;

use super::{
    Algorithm, Engine, EngineOutput, NativeBackend, SessionKind, SessionOptions,
    XlaBackend,
};
use crate::proptestx::Runner;

fn max_gamma_diff(a: &Posterior, b: &Posterior) -> f64 {
    assert_eq!(a.len(), b.len());
    a.gamma_flat()
        .iter()
        .zip(b.gamma_flat())
        .fold(0.0f64, |m, (&x, &y)| m.max((x - y).abs()))
}

fn assert_posteriors_match(name: &str, t: usize, got: &Posterior, want: &Posterior) {
    let d = max_gamma_diff(got, want);
    assert!(d <= 1e-12, "{name} T={t}: max |Δγ| = {d:e}");
    let dl = (got.log_likelihood() - want.log_likelihood()).abs();
    assert!(dl <= 1e-12, "{name} T={t}: |Δloglik| = {dl:e}");
}

fn assert_maps_match(name: &str, t: usize, got: &MapEstimate, want: &MapEstimate) {
    let dl = (got.log_prob - want.log_prob).abs();
    assert!(dl <= 1e-12, "{name} T={t}: |Δlogp| = {dl:e}");
    assert_eq!(got.path, want.path, "{name} T={t}: path mismatch");
}

/// The acceptance test: every Algorithm variant through `Engine` matches
/// its corresponding free function to ≤ 1e-12 on the Gilbert–Elliott
/// workload at T ∈ {100, 1000, 4096} — with one engine (and therefore
/// one reused workspace) across all 27 runs.
#[test]
fn all_nine_algorithms_match_free_functions() {
    let hmm = gilbert_elliott(GeParams::default());
    let opts = ScanOptions::default();
    let bw = BaumWelchOptions {
        max_iters: 4,
        backend: EStepBackend::ParallelScan,
        scan: opts,
        ..Default::default()
    };
    let mut engine = Engine::builder(hmm.clone())
        .scan_options(opts)
        .baum_welch_options(bw)
        .build();
    assert_eq!(engine.backend_name(), "native");

    let mut rng = Xoshiro256StarStar::seed_from_u64(0xE91E);
    for t in [100usize, 1000, 4096] {
        let tr = sample(&hmm, t, &mut rng);
        let ys = &tr.observations;
        for alg in Algorithm::ALL {
            if alg.task() == super::Task::Gaussian {
                // The Kalman tier runs on Lgssm models through
                // kalman::KalmanEngine; the discrete engine rejects it
                // with a typed error (covered further below).
                assert!(engine.run(alg, ys).is_err());
                continue;
            }
            let out = engine.run(alg, ys).unwrap();
            let name = alg.name();
            match alg {
                Algorithm::SpSeq => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::sp_seq(&hmm, ys).unwrap(),
                ),
                Algorithm::SpPar => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::sp_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::BsSeq => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::bs_seq(&hmm, ys).unwrap(),
                ),
                Algorithm::BsPar => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::bs_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::Viterbi => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::viterbi(&hmm, ys).unwrap(),
                ),
                Algorithm::MpSeq => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::mp_seq(&hmm, ys).unwrap(),
                ),
                Algorithm::MpPar => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::mp_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::MpPathPar => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::mp_path_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::BaumWelch => {
                    let got = out.as_training().unwrap();
                    let want = inference::baum_welch(&hmm, ys, bw).unwrap();
                    assert_eq!(got.iterations, want.iterations, "bw T={t}");
                    for (a, b) in got.loglik_curve.iter().zip(&want.loglik_curve) {
                        assert!((a - b).abs() <= 1e-12, "bw curve T={t}");
                    }
                    for (a, b) in got
                        .model
                        .transition()
                        .data()
                        .iter()
                        .zip(want.model.transition().data())
                    {
                        assert!((a - b).abs() <= 1e-12, "bw model T={t}");
                    }
                }
            }
        }
    }
}

/// Workspace reuse must be invisible: two consecutive runs on the same
/// input produce bit-identical results, including across interleaved
/// shape changes (grow / shrink the buffers between calls).
#[test]
fn workspace_reuse_is_deterministic() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut engine = Engine::builder(hmm.clone()).build();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xACE);
    let long = sample(&hmm, 500, &mut rng).observations;
    let short = sample(&hmm, 77, &mut rng).observations;

    let first_sp = engine.run(Algorithm::SpPar, &long).unwrap();
    let again_sp = engine.run(Algorithm::SpPar, &long).unwrap();
    assert_eq!(
        first_sp.as_posterior().unwrap(),
        again_sp.as_posterior().unwrap(),
        "consecutive SpPar runs must be bit-identical"
    );

    let first_mp = engine.run(Algorithm::MpPar, &long).unwrap();
    let first_bs = engine.run(Algorithm::BsPar, &long).unwrap();

    // Interleave a shorter sequence (shrinks every buffer)…
    engine.run(Algorithm::SpPar, &short).unwrap();
    engine.run(Algorithm::MpPar, &short).unwrap();
    engine.run(Algorithm::BsPar, &short).unwrap();

    // …then the original input must still reproduce exactly.
    let sp = engine.run(Algorithm::SpPar, &long).unwrap();
    let mp = engine.run(Algorithm::MpPar, &long).unwrap();
    let bs = engine.run(Algorithm::BsPar, &long).unwrap();
    assert_eq!(first_sp.as_posterior().unwrap(), sp.as_posterior().unwrap());
    assert_eq!(first_mp.as_map().unwrap(), mp.as_map().unwrap());
    assert_eq!(first_bs.as_posterior().unwrap(), bs.as_posterior().unwrap());
}

#[test]
fn run_batch_matches_individual_runs() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xBA7C);
    let seqs: Vec<Vec<u32>> = [40usize, 100, 7, 256, 1]
        .iter()
        .map(|&t| sample(&hmm, t, &mut rng).observations)
        .collect();
    let engine = Engine::builder(hmm.clone())
        .scan_options(ScanOptions { threads: 4, ..ScanOptions::default() })
        .build();

    let batch = engine.run_batch(Algorithm::SpPar, &seqs);
    assert_eq!(batch.len(), seqs.len());
    for (ys, out) in seqs.iter().zip(&batch) {
        let got = out.as_ref().unwrap().as_posterior().unwrap();
        // Batch runs may use a serial per-sequence schedule; compare
        // against the library default tolerance, not bitwise.
        let want = inference::sp_seq(&hmm, ys).unwrap();
        let d = max_gamma_diff(got, &want);
        assert!(d < 1e-9, "batch T={}: max |Δγ| = {d:e}", ys.len());
    }

    // Per-item errors: an invalid sequence fails its slot only.
    let mut with_bad = seqs.clone();
    with_bad[2] = vec![0, 9, 1]; // symbol 9 out of range (M = 2)
    let batch = engine.run_batch(Algorithm::MpPar, &with_bad);
    assert!(batch[2].is_err());
    for (i, out) in batch.iter().enumerate() {
        if i != 2 {
            assert!(out.is_ok(), "slot {i} should succeed");
        }
    }

    assert!(engine.run_batch(Algorithm::SpPar, &[]).is_empty());
}

#[test]
fn output_accessors_enforce_task_shape() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut engine = Engine::builder(hmm).build();
    let post = engine.run(Algorithm::SpPar, &[0, 1, 0]).unwrap();
    assert!(post.as_posterior().is_some());
    assert!(post.as_map().is_none());
    assert!(post.clone().into_map().is_err());
    assert!(post.into_posterior().is_ok());

    let map = engine.run(Algorithm::Viterbi, &[0, 1, 0]).unwrap();
    assert!(map.as_map().is_some());
    assert!(map.clone().into_training().is_err());

    let smoothed = engine.smooth(&[0, 1, 1]).unwrap();
    assert_eq!(smoothed.len(), 3);
    let decoded = engine.decode_map(&[0, 1, 1]).unwrap();
    assert_eq!(decoded.path.len(), 3);
}

// ---------------------------------------------------------------------------
// Streaming sessions (the checkpoint-resume acceptance bar)
// ---------------------------------------------------------------------------

/// The streaming acceptance test: *any* split of a sequence into
/// random-size `push` calls yields `finish()` / `finish_map()`
/// bit-identical to the one-shot `Engine::run` under the same scan
/// options — including T = 1, pushes smaller than the block, and
/// single-thread scan dispatch.
#[test]
fn session_finish_bit_identical_over_random_push_splits() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut runner = Runner::new("session-push-splits");
    runner.run(12, |r| {
        let t = 1 + r.below(500) as usize;
        let block = 1 + r.below(48) as usize;
        let opts = ScanOptions {
            threads: 1 + r.below(4) as usize,
            min_parallel_work: 8,
            ..ScanOptions::default().with_block(block)
        };
        let mut engine = Engine::builder(hmm.clone()).scan_options(opts).build();
        let ys = sample(&hmm, t, r).observations;
        let want =
            engine.run(Algorithm::SpPar, &ys).unwrap().into_posterior().unwrap();
        let want_map =
            engine.run(Algorithm::MpPar, &ys).unwrap().into_map().unwrap();

        let track_map = r.below(2) == 0;
        let mut session = engine
            .open_session(SessionOptions { track_map, ..SessionOptions::default() });
        assert_eq!(session.block(), block);
        let mut i = 0;
        while i < t {
            let j = (i + 1 + r.below(7) as usize).min(t);
            session.push(&ys[i..j]).unwrap();
            i = j;
        }
        assert_eq!(session.len(), t);
        let got = session.finish().unwrap();
        assert_eq!(got, want, "finish T={t} B={block}");
        let got_map = session.finish_map().unwrap();
        assert_eq!(got_map, want_map, "finish_map T={t} B={block}");
        // finish() leaves the session usable — repeat is idempotent.
        assert_eq!(session.finish().unwrap(), want);
    });
}

#[test]
fn session_edge_cases_t_one_and_bad_pushes() {
    let hmm = gilbert_elliott(GeParams::default());
    let opts = ScanOptions::default().with_block(64);
    let mut engine = Engine::builder(hmm.clone()).scan_options(opts).build();
    let mut s = engine.open_session(SessionOptions::default());
    assert!(s.is_empty());
    assert!(s.filtered().is_err());
    assert!(s.finish().is_err());
    assert!(s.smoothed_lag(4).is_err());
    assert!(s.map_lag(4).is_err());
    s.push(&[]).unwrap(); // empty append is a no-op
    assert!(s.is_empty());

    s.push(&[1]).unwrap();
    let want =
        engine.run(Algorithm::SpPar, &[1]).unwrap().into_posterior().unwrap();
    assert_eq!(s.finish().unwrap(), want);
    // At T = 1 the filtering and smoothing marginals coincide.
    let f = s.filtered().unwrap();
    assert_eq!(f.step, 1);
    assert!((f.log_likelihood - want.log_likelihood()).abs() < 1e-12);
    for (p, g) in f.probs.iter().zip(want.gamma(0)) {
        assert!((p - g).abs() < 1e-12);
    }

    // Out-of-range symbols are rejected atomically: no partial append.
    assert!(s.push(&[0, 9]).is_err());
    assert_eq!(s.len(), 1);
    assert_eq!(s.observations(), &[1u32][..]);
}

#[test]
fn session_filtered_tracks_forward_likelihood() {
    let hmm = gilbert_elliott(GeParams::default());
    let engine = Engine::builder(hmm.clone())
        .scan_options(ScanOptions::default().with_block(16))
        .build();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xF117);
    let ys = sample(&hmm, 120, &mut rng).observations;
    let mut s = engine.open_session(SessionOptions::default());
    for k in 0..ys.len() {
        s.push(&ys[k..k + 1]).unwrap();
        let f = s.filtered().unwrap();
        let want = inference::sp_seq(&hmm, &ys[..=k]).unwrap().log_likelihood();
        assert!(
            (f.log_likelihood - want).abs() <= 1e-9 * (1.0 + want.abs()),
            "k={k}: {} vs {want}",
            f.log_likelihood
        );
        let sum: f64 = f.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "k={k}: filtered not normalized");
    }
}

#[test]
fn session_fixed_lag_matches_full_reruns() {
    // Asymmetric 3-state model (no exact MAP ties, unlike GE at long T)
    // so the fixed-lag MAP window can be compared exactly.
    let hmm = crate::hmm::Hmm::new(
        crate::linalg::Mat::from_vec(
            3,
            3,
            vec![0.71, 0.17, 0.12, 0.23, 0.59, 0.18, 0.09, 0.33, 0.58],
        ),
        crate::linalg::Mat::from_vec(
            3,
            3,
            vec![0.61, 0.26, 0.13, 0.19, 0.47, 0.34, 0.27, 0.12, 0.61],
        ),
        vec![0.5, 0.3, 0.2],
    )
    .unwrap();
    let opts = ScanOptions {
        threads: 3,
        min_parallel_work: 8,
        ..ScanOptions::default().with_block(24)
    };
    let engine = Engine::builder(hmm.clone()).scan_options(opts).build();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0x1A6);
    let ys = sample(&hmm, 300, &mut rng).observations;

    let mut s = engine
        .open_session(SessionOptions { track_map: true, ..SessionOptions::default() });
    let mut pushed = 0usize;
    for chunk in [13usize, 1, 40, 96, 150] {
        let next = (pushed + chunk).min(ys.len());
        s.push(&ys[pushed..next]).unwrap();
        pushed = next;
        let t = pushed;
        for lag in [1usize, 17, 64] {
            let win = s.smoothed_lag(lag).unwrap();
            let full =
                inference::sp_par(&hmm, &ys[..t], s.scan_options()).unwrap();
            let n = win.posterior.len();
            assert_eq!(n, t.min(lag));
            assert_eq!(win.start, t - n);
            assert!(win.rescan_width >= n && win.rescan_width <= n + s.block());
            for j in 0..n {
                for st in 0..3 {
                    let got = win.posterior.gamma(j)[st];
                    let want = full.gamma(win.start + j)[st];
                    assert!(
                        (got - want).abs() < 1e-10,
                        "t={t} lag={lag} j={j}: {got} vs {want}"
                    );
                }
            }
            assert!(
                (win.posterior.log_likelihood() - full.log_likelihood()).abs()
                    <= 1e-9 * (1.0 + full.log_likelihood().abs())
            );

            let dec = s.map_lag(lag).unwrap();
            let full_map =
                inference::mp_par(&hmm, &ys[..t], s.scan_options()).unwrap();
            assert_eq!(dec.start, t - n);
            assert_eq!(
                dec.path,
                full_map.path[dec.start..t],
                "t={t} lag={lag} MAP window"
            );
            assert!(
                (dec.log_prob - full_map.log_prob).abs()
                    <= 1e-9 * (1.0 + full_map.log_prob.abs())
            );
        }
    }
}

#[test]
fn session_snapshot_resume_is_bit_identical() {
    let hmm = gilbert_elliott(GeParams::default());
    let opts = ScanOptions::default().with_block(32);
    let mut engine = Engine::builder(hmm.clone()).scan_options(opts).build();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0x5A7);
    let ys = sample(&hmm, 333, &mut rng).observations;

    let mut live = engine
        .open_session(SessionOptions { track_map: true, ..SessionOptions::default() });
    live.push(&ys[..150]).unwrap();

    // Round-trip through the JSON wire format (exact f64 serde).
    let wire = live.snapshot().to_string_compact();
    let snap = crate::jsonx::Json::parse(&wire).unwrap();
    let mut resumed = engine.resume_session(&snap).unwrap();
    assert_eq!(resumed.len(), 150);

    live.push(&ys[150..]).unwrap();
    resumed.push(&ys[150..]).unwrap();
    let a = live.finish().unwrap();
    let b = resumed.finish().unwrap();
    assert_eq!(a, b, "resume diverged from the live session");
    let want =
        engine.run(Algorithm::SpPar, &ys).unwrap().into_posterior().unwrap();
    assert_eq!(a, want, "streamed result diverged from one-shot");
    assert_eq!(live.finish_map().unwrap(), resumed.finish_map().unwrap());

    // An empty-session snapshot round-trips too.
    let empty = engine.open_session(SessionOptions::default());
    let resumed = engine.resume_session(&empty.snapshot()).unwrap();
    assert!(resumed.is_empty());

    // Malformed snapshots are rejected.
    assert!(engine.resume_session(&crate::jsonx::Json::Null).is_err());
    let bad = crate::jsonx::Json::parse(r#"{"block": 8, "ys": [0, 1]}"#).unwrap();
    assert!(engine.resume_session(&bad).is_err());
    // Wrong-shaped summaries are a typed error, not a downstream panic.
    let bad_shape = crate::jsonx::Json::parse(
        r#"{"version": 1, "block": 8, "track_map": false,
            "ys": [0, 1, 0, 1, 0, 1, 0, 1],
            "sp_summaries": [{"mat": {"rows": 2, "cols": 2,
                                      "data": [1, 0, 0, 1]},
                              "log_scale": 0}],
            "sp_tail": null}"#,
    )
    .unwrap();
    assert!(engine.resume_session(&bad_shape).is_err());
    // Unknown snapshot versions are rejected up front.
    let future = crate::jsonx::Json::parse(r#"{"version": 3, "block": 8}"#).unwrap();
    assert!(engine.resume_session(&future).is_err());

    // A version-1 snapshot (decimal payloads, the pre-compression
    // encoding) resumes bit-identically: rewrite the packed payloads to
    // decimal arrays and downgrade the version stamp.
    let mut live = engine
        .open_session(SessionOptions { track_map: true, ..SessionOptions::default() });
    live.push(&ys[..200]).unwrap();
    let legacy = match crate::elements::serde::to_decimal_json(&live.snapshot()) {
        crate::jsonx::Json::Obj(mut o) => {
            assert!(o.get("ys").and_then(|v| v.as_arr()).is_some());
            o.insert("version".to_string(), crate::jsonx::Json::Num(1.0));
            crate::jsonx::Json::Obj(o)
        }
        other => panic!("snapshot must be an object, got {other:?}"),
    };
    let mut resumed = engine.resume_session(&legacy).unwrap();
    live.push(&ys[200..]).unwrap();
    resumed.push(&ys[200..]).unwrap();
    assert_eq!(
        live.finish().unwrap(),
        resumed.finish().unwrap(),
        "decimal (v1) snapshot resume diverged"
    );
}

/// Bayes-kind sessions stream the BS-Par element algebra: any split of
/// a sequence into random pushes yields `finish()` bit-identical to the
/// one-shot `Engine::run(BsPar, ..)` under the same scan options.
#[test]
fn bayes_session_finish_bit_identical_over_random_push_splits() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut runner = Runner::new("bayes-session-splits");
    runner.run(10, |r| {
        let t = 1 + r.below(400) as usize;
        let block = 1 + r.below(48) as usize;
        let opts = ScanOptions {
            threads: 1 + r.below(4) as usize,
            min_parallel_work: 8,
            ..ScanOptions::default().with_block(block)
        };
        let mut engine = Engine::builder(hmm.clone()).scan_options(opts).build();
        let ys = sample(&hmm, t, r).observations;
        let want =
            engine.run(Algorithm::BsPar, &ys).unwrap().into_posterior().unwrap();

        let mut s = engine.open_session(SessionOptions {
            kind: SessionKind::Bayes,
            ..SessionOptions::default()
        });
        assert_eq!(s.kind(), SessionKind::Bayes);
        let mut i = 0;
        while i < t {
            let j = (i + 1 + r.below(7) as usize).min(t);
            s.push(&ys[i..j]).unwrap();
            i = j;
        }
        let got = s.finish().unwrap();
        assert_eq!(got, want, "bayes finish T={t} B={block}");
        // finish() leaves the session usable — repeat is idempotent.
        assert_eq!(s.finish().unwrap(), want);
    });
}

#[test]
fn bayes_session_filtered_tracks_forward_filter() {
    // Per-step probabilities against a hand-rolled forward filter and
    // the running log-likelihood against sp_seq (filter-derived).
    let hmm = gilbert_elliott(GeParams::default());
    let engine = Engine::builder(hmm.clone())
        .scan_options(ScanOptions::default().with_block(16))
        .build();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xB5F1);
    let ys = sample(&hmm, 120, &mut rng).observations;
    let mut s = engine.open_session(SessionOptions {
        kind: SessionKind::Bayes,
        ..SessionOptions::default()
    });
    let d = hmm.num_states();
    let mut f = vec![0.0f64; d];
    for (k, &y) in ys.iter().enumerate() {
        s.push(&[y]).unwrap();
        let e = hmm.emission_col(y);
        if k == 0 {
            for j in 0..d {
                f[j] = hmm.prior()[j] * e[j];
            }
        } else {
            let prev = f.clone();
            for j in 0..d {
                let mut acc = 0.0;
                for (i, &p) in prev.iter().enumerate() {
                    acc += p * hmm.transition()[(i, j)];
                }
                f[j] = acc * e[j];
            }
        }
        let sum: f64 = f.iter().sum();
        f.iter_mut().for_each(|v| *v /= sum);
        let got = s.filtered().unwrap();
        assert_eq!(got.step, k + 1);
        for (j, &fj) in f.iter().enumerate() {
            assert!(
                (got.probs[j] - fj).abs() < 1e-9,
                "k={k} j={j}: {} vs {fj}",
                got.probs[j]
            );
        }
        let want_ll =
            inference::sp_seq(&hmm, &ys[..=k]).unwrap().log_likelihood();
        assert!(
            (got.log_likelihood - want_ll).abs() <= 1e-9 * (1.0 + want_ll.abs()),
            "k={k}: {} vs {want_ll}",
            got.log_likelihood
        );
    }
    // Fixed-lag and MAP queries are typed errors for this family, and a
    // failed query leaves the session usable.
    assert!(s.smoothed_lag(4).is_err());
    assert!(s.map_lag(4).is_err());
    assert!(s.finish_map().is_err());
    assert!(s.finish().is_ok());
    assert_eq!(s.len(), 120);
}

/// The eviction acceptance bar: repeated spill → restore cycles through
/// the JSON wire format, interleaved with random pushes, stay bitwise
/// identical to the never-evicted session and the one-shot run — for
/// both element families.
#[test]
fn session_spill_restore_cycles_bit_identical() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut runner = Runner::new("session-spill-cycles");
    runner.run(8, |r| {
        let t = 1 + r.below(400) as usize;
        let block = 1 + r.below(40) as usize;
        let opts = ScanOptions {
            threads: 1 + r.below(3) as usize,
            min_parallel_work: 8,
            ..ScanOptions::default().with_block(block)
        };
        let mut engine = Engine::builder(hmm.clone()).scan_options(opts).build();
        let ys = sample(&hmm, t, r).observations;

        for kind in [SessionKind::SumProduct, SessionKind::Bayes] {
            let opts = SessionOptions {
                kind,
                track_map: kind == SessionKind::SumProduct,
                ..SessionOptions::default()
            };
            let mut live = engine.open_session(opts);
            let mut restored = engine.open_session(opts);
            let mut i = 0;
            while i < t {
                let j = (i + 1 + r.below(23) as usize).min(t);
                live.push(&ys[i..j]).unwrap();
                restored.push(&ys[i..j]).unwrap();
                if r.below(2) == 0 {
                    // Spill/restore cycle through the wire format.
                    let wire = restored.snapshot().to_string_compact();
                    let snap = crate::jsonx::Json::parse(&wire).unwrap();
                    restored = engine.resume_session(&snap).unwrap();
                }
                i = j;
            }
            let a = live.finish().unwrap();
            let b = restored.finish().unwrap();
            assert_eq!(a, b, "{kind:?} spill cycles diverged (T={t} B={block})");
            let alg = match kind {
                SessionKind::SumProduct => Algorithm::SpPar,
                SessionKind::Bayes => Algorithm::BsPar,
            };
            let want = engine.run(alg, &ys).unwrap().into_posterior().unwrap();
            assert_eq!(a, want, "{kind:?} diverged from one-shot (T={t})");
            if kind == SessionKind::SumProduct {
                assert_eq!(
                    live.finish_map().unwrap(),
                    restored.finish_map().unwrap(),
                    "map diverged (T={t} B={block})"
                );
            }
        }
    });
}

#[test]
fn session_scan_options_reproduce_finish_on_fresh_engine() {
    // An engine with *unpinned* options: the session picks the default
    // block, and its published scan options are the reproduction recipe.
    let hmm = gilbert_elliott(GeParams::default());
    let engine = Engine::builder(hmm.clone()).build();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xD0C);
    let ys = sample(&hmm, 700, &mut rng).observations;
    let mut s = engine.open_session(SessionOptions::default());
    assert_eq!(s.block(), super::DEFAULT_SESSION_BLOCK);
    for chunk in ys.chunks(97) {
        s.push(chunk).unwrap();
    }
    let got = s.finish().unwrap();
    let mut twin = Engine::builder(hmm).scan_options(s.scan_options()).build();
    let want =
        twin.run(Algorithm::SpPar, &ys).unwrap().into_posterior().unwrap();
    assert_eq!(got, want);
}

// ---------------------------------------------------------------------------
// XLA backend (stub executor — exercises lookup, marshalling, decode)
// ---------------------------------------------------------------------------

/// Fabricated artifact outputs keyed by entry family.
struct StubExec {
    gamma: Vec<f32>,
    loglik: f32,
    path: Vec<i32>,
    log_prob: f32,
}

impl ArtifactExec for StubExec {
    fn run(&self, artifact: &str, inputs: Vec<Value>) -> crate::Result<Vec<Value>> {
        // The engine must marshal the standard 5-input layout.
        assert_eq!(inputs.len(), 5);
        if artifact.starts_with("sp") {
            Ok(vec![
                Value::F32(self.gamma.clone(), vec![self.gamma.len() / 4, 4]),
                Value::scalar_f32(self.loglik),
            ])
        } else {
            Ok(vec![
                Value::I32(self.path.clone(), vec![self.path.len()]),
                Value::scalar_f32(self.log_prob),
            ])
        }
    }
}

fn stub_manifest() -> Arc<Manifest> {
    let json = r#"{
      "version": 1, "interchange": "hlo-text",
      "artifacts": [
        {"name": "sp_par_T8", "entry": "sp_par", "kind": "core",
         "t": 8, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
        {"name": "mp_par_T8", "entry": "mp_par", "kind": "core",
         "t": 8, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []}
      ]
    }"#;
    Arc::new(Manifest::parse(json, PathBuf::from("/x")).unwrap())
}

fn xla_engine(stub: StubExec) -> Engine {
    let backend = XlaBackend::new(Arc::new(stub), stub_manifest());
    Engine::builder(gilbert_elliott(GeParams::default()))
        .backend(Arc::new(backend))
        .build()
}

#[test]
fn xla_backend_decodes_core_outputs() {
    let gamma: Vec<f32> = (0..32).map(|i| i as f32).collect(); // capacity 8 × D 4
    let mut engine = xla_engine(StubExec {
        gamma,
        loglik: -3.5,
        path: vec![0, 1, 2, 3, 1, 0, 0, 0],
        log_prob: -7.25,
    });
    assert_eq!(engine.backend_name(), "xla");

    // T = 5 pads into the T = 8 artifact; padding rows are discarded.
    let ys = vec![0u32, 1, 1, 0, 1];
    let post = engine.run(Algorithm::SpPar, &ys).unwrap().into_posterior().unwrap();
    assert_eq!(post.len(), 5);
    assert_eq!(post.gamma(0), &[0.0, 1.0, 2.0, 3.0]);
    assert_eq!(post.gamma(4), &[16.0, 17.0, 18.0, 19.0]);
    assert_eq!(post.log_likelihood(), -3.5);

    let est = engine.run(Algorithm::MpPar, &ys).unwrap().into_map().unwrap();
    assert_eq!(est.path, vec![0, 1, 2, 3, 1]);
    assert_eq!(est.log_prob, -7.25);

    // No artifact covers T > capacity, sequential entries, or training.
    assert!(engine.run(Algorithm::SpPar, &vec![0u32; 9]).is_err());
    assert!(engine.run(Algorithm::SpSeq, &ys).is_err());
    assert!(engine.run(Algorithm::BaumWelch, &ys).is_err());
}

#[test]
fn xla_backend_rejects_out_of_range_states() {
    let mut engine = xla_engine(StubExec {
        gamma: vec![0.0; 32],
        loglik: 0.0,
        path: vec![0, 1, 9, 0, 0, 0, 0, 0], // state 9 ≥ D = 4
        log_prob: 0.0,
    });
    let err = engine.run(Algorithm::MpPar, &[0, 1, 1]).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
}

#[test]
fn explicit_native_backend_matches_default() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut a = Engine::builder(hmm.clone()).build();
    let mut b = Engine::builder(hmm)
        .backend(Arc::new(NativeBackend))
        .build();
    let ys = vec![0u32, 1, 0, 1, 1, 0];
    let pa = a.run(Algorithm::SpPar, &ys).unwrap();
    let pb = b.run(Algorithm::SpPar, &ys).unwrap();
    assert_eq!(pa.as_posterior().unwrap(), pb.as_posterior().unwrap());
    match a.run(Algorithm::BaumWelch, &ys).unwrap() {
        EngineOutput::Training(res) => assert!(res.iterations > 0),
        other => panic!("expected training output, got {other:?}"),
    }
}

/// Kalman-kind sessions stream the affine-Gaussian element algebra over
/// the u32 word channel: any split of the encoded stream into random
/// pushes — including splits that tear an f64 or an observation row —
/// yields `finish()` bit-identical to the one-shot
/// `KalmanEngine::run(KsPar, ..)` under the same scan options.
#[test]
fn kalman_session_finish_bit_identical_over_random_push_splits() {
    use crate::kalman::{obs_to_words, KalmanEngine, Lgssm};
    use crate::kalman::tests_support::tracking_obs;

    let mut runner = Runner::new("kalman-session-splits");
    runner.run(8, |r| {
        let t = 1 + r.below(200) as usize;
        let block = 1 + r.below(48) as usize;
        let opts = ScanOptions {
            threads: 1 + r.below(4) as usize,
            min_parallel_work: 8,
            ..ScanOptions::default().with_block(block)
        };
        let model = Lgssm::constant_velocity(0.1, 0.8, 0.5);
        let obs = tracking_obs(&model, t, r.next_u64());
        let words = obs_to_words(&obs);
        let mut engine =
            KalmanEngine::new(Lgssm::constant_velocity(0.1, 0.8, 0.5))
                .with_scan_options(opts);
        let want = engine.run(Algorithm::KsPar, &obs).unwrap();

        let mut s = engine.open_session(SessionOptions::default());
        assert_eq!(s.kind(), SessionKind::Kalman);
        assert_eq!(s.block(), block);
        let mut i = 0;
        while i < words.len() {
            // Arbitrary word-boundary splits: chunks of 1..=9 words tear
            // f64 halves and observation rows alike.
            let j = (i + 1 + r.below(9) as usize).min(words.len());
            s.push(&words[i..j]).unwrap();
            i = j;
        }
        let got = s.finish().unwrap();
        assert_eq!(
            got.gamma_flat(),
            want.gamma_flat(),
            "kalman finish T={t} B={block}"
        );
        assert_eq!(
            got.log_likelihood().to_bits(),
            want.log_likelihood().to_bits(),
            "kalman finish loglik T={t} B={block}"
        );
        // finish() leaves the session usable — repeat is idempotent.
        assert_eq!(s.finish().unwrap().gamma_flat(), want.gamma_flat());
        // filtered() reports complete rows and the packed Gaussian.
        let f = s.filtered().unwrap();
        let n = 4;
        assert_eq!(f.step, t);
        assert_eq!(f.probs.len(), n + n * n);
    });
}

/// Kalman session snapshots restore bit-identically — including a
/// snapshot taken with a torn observation row buffered — and the
/// cross-family resume paths reject each other's snapshots.
#[test]
fn kalman_session_snapshot_resume_is_bit_identical() {
    use crate::kalman::{obs_to_words, KalmanEngine, Lgssm};
    use crate::kalman::tests_support::tracking_obs;

    let model = Lgssm::constant_velocity(0.1, 0.8, 0.5);
    let obs = tracking_obs(&model, 90, 0xCAFE);
    let words = obs_to_words(&obs);
    let engine = KalmanEngine::new(Lgssm::constant_velocity(0.1, 0.8, 0.5))
        .with_scan_options(ScanOptions::default().with_block(16));

    // Split at an odd word offset: the snapshot carries a torn f64.
    let cut = 4 * 37 + 3;
    let mut live = engine.open_session(SessionOptions::default());
    live.push(&words[..cut]).unwrap();

    let wire = live.snapshot().to_string_compact();
    let snap = crate::jsonx::Json::parse(&wire).unwrap();
    let mut resumed = engine.resume_session(&snap).unwrap();
    assert_eq!(resumed.len(), cut);
    assert_eq!(
        live.filtered().unwrap(),
        resumed.filtered().unwrap(),
        "restored filtered state diverged (loglik must restore exactly)"
    );

    live.push(&words[cut..]).unwrap();
    resumed.push(&words[cut..]).unwrap();
    let a = live.finish().unwrap();
    let b = resumed.finish().unwrap();
    assert_eq!(a.gamma_flat(), b.gamma_flat(), "resume diverged from live");
    assert_eq!(a.log_likelihood().to_bits(), b.log_likelihood().to_bits());

    // An empty-session snapshot round-trips.
    let empty = engine.open_session(SessionOptions::default());
    let resumed = engine.resume_session(&empty.snapshot()).unwrap();
    assert!(resumed.is_empty());

    // Cross-family confusion is a typed error, both directions.
    let hmm = gilbert_elliott(GeParams::default());
    let discrete = Engine::builder(hmm).build();
    assert!(discrete.resume_session(&snap).is_err());
    let sp_snap = discrete.open_session(SessionOptions::default()).snapshot();
    assert!(engine.resume_session(&sp_snap).is_err());
}

/// The Kalman session surface: unsupported queries are typed errors,
/// appends reject non-finite rows atomically, and a torn row blocks
/// `finish` but not buffering.
#[test]
fn kalman_session_guards_and_torn_rows() {
    use crate::kalman::{obs_to_words, KalmanEngine, Lgssm};

    let engine = KalmanEngine::new(Lgssm::constant_velocity(0.1, 1.0, 0.5))
        .with_scan_options(ScanOptions::default().with_block(8));
    let mut s = engine.open_session(SessionOptions::default());

    // Nothing pushed: filtered/finish are errors.
    assert!(s.filtered().is_err());
    assert!(s.finish().is_err());

    // A partial row buffers; queries still see no complete row.
    let row = obs_to_words(&[1.0, 2.0]);
    s.push(&row[..3]).unwrap();
    assert_eq!(s.len(), 3);
    assert!(s.filtered().is_err());
    assert!(s.finish().is_err());
    s.push(&row[3..]).unwrap();
    assert_eq!(s.filtered().unwrap().step, 1);

    // A torn row blocks finish until completed.
    s.push(&row[..1]).unwrap();
    assert!(s.finish().is_err());
    s.push(&row[1..]).unwrap();
    assert_eq!(s.filtered().unwrap().step, 2);
    assert!(s.finish().is_ok());

    // Non-finite rows are rejected atomically: the words that would
    // complete the bad row are not ingested.
    let bad = obs_to_words(&[f64::NAN, 7.0]);
    let before = s.len();
    assert!(s.push(&bad).is_err());
    assert_eq!(s.len(), before, "rejected append must not ingest words");
    assert_eq!(s.filtered().unwrap().step, 2);

    // Discrete-family queries are typed rejections, not panics.
    assert!(s.smoothed_lag(4).is_err());
    assert!(s.map_lag(4).is_err());
    assert!(s.finish_map().is_err());
}

/// `Engine::open_session` cannot host the Gaussian family — documented
/// panic (the coordinator routes by model kind before ever getting
/// here).
#[test]
#[should_panic(expected = "kalman sessions are opened")]
fn discrete_engine_panics_on_kalman_session_kind() {
    let hmm = gilbert_elliott(GeParams::default());
    let engine = Engine::builder(hmm).build();
    let _ = engine.open_session(SessionOptions {
        kind: SessionKind::Kalman,
        ..SessionOptions::default()
    });
}

// ---------------------------------------------------------------------------
// Kernel tier (the on/off bit-identity acceptance bar)
// ---------------------------------------------------------------------------

fn assert_outputs_bit_identical(label: &str, a: &EngineOutput, b: &EngineOutput) {
    use crate::proptestx::assert_bits_eq;
    match (a, b) {
        (EngineOutput::Posterior(x), EngineOutput::Posterior(y)) => {
            assert_bits_eq(label, x.gamma_flat(), y.gamma_flat());
            assert_bits_eq(label, &[x.log_likelihood()], &[y.log_likelihood()]);
        }
        (EngineOutput::Map(x), EngineOutput::Map(y)) => {
            assert_eq!(x.path, y.path, "{label}: MAP path diverged");
            assert_bits_eq(label, &[x.log_prob], &[y.log_prob]);
        }
        (EngineOutput::Training(x), EngineOutput::Training(y)) => {
            assert_eq!(x.iterations, y.iterations, "{label}: iterations");
            assert_bits_eq(label, &x.loglik_curve, &y.loglik_curve);
            assert_bits_eq(label, x.model.transition().data(), y.model.transition().data());
            assert_bits_eq(label, x.model.emission().data(), y.model.emission().data());
            assert_bits_eq(label, x.model.prior(), y.model.prior());
        }
        _ => panic!("{label}: output kinds diverged"),
    }
}

/// The kernel-tier acceptance bar: every [`Algorithm`] variant produces
/// bit-identical output with the specialized kernels force-enabled vs
/// force-disabled, across D ∈ {2, 4, 8, 16} (every microkernel shape)
/// and T ∈ {1, 100, 4096}. The discrete variants run on random D-state
/// HMMs; the four Gaussian variants run through `KalmanEngine` on the
/// 4-state constant-velocity model (the D = 4 kernel) in the D = 4 leg.
#[test]
fn all_thirteen_algorithms_bit_identical_kernels_on_vs_off() {
    use crate::kalman::tests_support::tracking_obs;
    use crate::kalman::{KalmanEngine, Lgssm};
    use crate::linalg::kernels::{set_kernels_enabled, toggle_guard};
    use crate::linalg::Mat;
    use crate::proptestx::gen;

    let _guard = toggle_guard();
    let opts = ScanOptions {
        threads: 2,
        min_parallel_work: 4,
        ..ScanOptions::default()
    };
    let bw = BaumWelchOptions {
        max_iters: 2,
        backend: EStepBackend::ParallelScan,
        scan: opts,
        ..Default::default()
    };
    let m = 3usize;
    let mut rng = Xoshiro256StarStar::seed_from_u64(0x13A1);
    for d in [2usize, 4, 8, 16] {
        let pi = Mat::from_vec(d, d, gen::stochastic_matrix(&mut rng, d));
        let mut obs = Mat::zeros(d, m);
        for row in 0..d {
            let vals = gen::prob_vector(&mut rng, m);
            for (c, v) in vals.into_iter().enumerate() {
                obs[(row, c)] = v;
            }
        }
        let hmm = crate::hmm::Hmm::new(pi, obs, gen::prob_vector(&mut rng, d)).unwrap();
        let mut engine = Engine::builder(hmm)
            .scan_options(opts)
            .baum_welch_options(bw)
            .build();
        for t in [1usize, 100, 4096] {
            let ys = gen::obs_seq(&mut rng, m, t);
            for alg in Algorithm::ALL {
                if alg.task() == super::Task::Gaussian {
                    continue; // served by KalmanEngine below
                }
                set_kernels_enabled(true);
                let on = engine.run(alg, &ys).unwrap();
                set_kernels_enabled(false);
                let off = engine.run(alg, &ys).unwrap();
                set_kernels_enabled(true);
                let label = format!("{} D={d} T={t}", alg.name());
                assert_outputs_bit_identical(&label, &on, &off);
            }
            if d == 4 {
                let model = Lgssm::constant_velocity(0.1, 0.8, 0.5);
                let zs = tracking_obs(&model, t, 0xBEEF ^ t as u64);
                let mut ke = KalmanEngine::new(model).with_scan_options(opts);
                for alg in Algorithm::ALL {
                    if alg.task() != super::Task::Gaussian {
                        continue;
                    }
                    set_kernels_enabled(true);
                    let on = ke.run(alg, &zs).unwrap();
                    set_kernels_enabled(false);
                    let off = ke.run(alg, &zs).unwrap();
                    set_kernels_enabled(true);
                    let label = format!("{} T={t}", alg.name());
                    crate::proptestx::assert_bits_eq(
                        &label,
                        on.gamma_flat(),
                        off.gamma_flat(),
                    );
                    crate::proptestx::assert_bits_eq(
                        &label,
                        &[on.log_likelihood()],
                        &[off.log_likelihood()],
                    );
                }
            }
        }
    }
}

//! Engine test suite: equivalence of every [`Algorithm`] against its
//! free function (the acceptance bar for the unified API), workspace
//! reuse determinism, batch semantics, and the XLA backend decode path
//! (via a stub executor — no PJRT needed).

use std::path::PathBuf;
use std::sync::Arc;

use crate::hmm::{gilbert_elliott, sample, GeParams};
use crate::inference::{
    self, BaumWelchOptions, EStepBackend, MapEstimate, Posterior,
};
use crate::rng::Xoshiro256StarStar;
use crate::runtime::{ArtifactExec, Manifest, Value};
use crate::scan::ScanOptions;

use super::{Algorithm, Engine, EngineOutput, NativeBackend, XlaBackend};

fn max_gamma_diff(a: &Posterior, b: &Posterior) -> f64 {
    assert_eq!(a.len(), b.len());
    a.gamma_flat()
        .iter()
        .zip(b.gamma_flat())
        .fold(0.0f64, |m, (&x, &y)| m.max((x - y).abs()))
}

fn assert_posteriors_match(name: &str, t: usize, got: &Posterior, want: &Posterior) {
    let d = max_gamma_diff(got, want);
    assert!(d <= 1e-12, "{name} T={t}: max |Δγ| = {d:e}");
    let dl = (got.log_likelihood() - want.log_likelihood()).abs();
    assert!(dl <= 1e-12, "{name} T={t}: |Δloglik| = {dl:e}");
}

fn assert_maps_match(name: &str, t: usize, got: &MapEstimate, want: &MapEstimate) {
    let dl = (got.log_prob - want.log_prob).abs();
    assert!(dl <= 1e-12, "{name} T={t}: |Δlogp| = {dl:e}");
    assert_eq!(got.path, want.path, "{name} T={t}: path mismatch");
}

/// The acceptance test: every Algorithm variant through `Engine` matches
/// its corresponding free function to ≤ 1e-12 on the Gilbert–Elliott
/// workload at T ∈ {100, 1000, 4096} — with one engine (and therefore
/// one reused workspace) across all 27 runs.
#[test]
fn all_nine_algorithms_match_free_functions() {
    let hmm = gilbert_elliott(GeParams::default());
    let opts = ScanOptions::default();
    let bw = BaumWelchOptions {
        max_iters: 4,
        backend: EStepBackend::ParallelScan,
        scan: opts,
        ..Default::default()
    };
    let mut engine = Engine::builder(hmm.clone())
        .scan_options(opts)
        .baum_welch_options(bw)
        .build();
    assert_eq!(engine.backend_name(), "native");

    let mut rng = Xoshiro256StarStar::seed_from_u64(0xE91E);
    for t in [100usize, 1000, 4096] {
        let tr = sample(&hmm, t, &mut rng);
        let ys = &tr.observations;
        for alg in Algorithm::ALL {
            let out = engine.run(alg, ys).unwrap();
            let name = alg.name();
            match alg {
                Algorithm::SpSeq => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::sp_seq(&hmm, ys).unwrap(),
                ),
                Algorithm::SpPar => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::sp_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::BsSeq => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::bs_seq(&hmm, ys).unwrap(),
                ),
                Algorithm::BsPar => assert_posteriors_match(
                    name, t, out.as_posterior().unwrap(),
                    &inference::bs_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::Viterbi => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::viterbi(&hmm, ys).unwrap(),
                ),
                Algorithm::MpSeq => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::mp_seq(&hmm, ys).unwrap(),
                ),
                Algorithm::MpPar => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::mp_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::MpPathPar => assert_maps_match(
                    name, t, out.as_map().unwrap(),
                    &inference::mp_path_par(&hmm, ys, opts).unwrap(),
                ),
                Algorithm::BaumWelch => {
                    let got = out.as_training().unwrap();
                    let want = inference::baum_welch(&hmm, ys, bw).unwrap();
                    assert_eq!(got.iterations, want.iterations, "bw T={t}");
                    for (a, b) in got.loglik_curve.iter().zip(&want.loglik_curve) {
                        assert!((a - b).abs() <= 1e-12, "bw curve T={t}");
                    }
                    for (a, b) in got
                        .model
                        .transition()
                        .data()
                        .iter()
                        .zip(want.model.transition().data())
                    {
                        assert!((a - b).abs() <= 1e-12, "bw model T={t}");
                    }
                }
            }
        }
    }
}

/// Workspace reuse must be invisible: two consecutive runs on the same
/// input produce bit-identical results, including across interleaved
/// shape changes (grow / shrink the buffers between calls).
#[test]
fn workspace_reuse_is_deterministic() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut engine = Engine::builder(hmm.clone()).build();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xACE);
    let long = sample(&hmm, 500, &mut rng).observations;
    let short = sample(&hmm, 77, &mut rng).observations;

    let first_sp = engine.run(Algorithm::SpPar, &long).unwrap();
    let again_sp = engine.run(Algorithm::SpPar, &long).unwrap();
    assert_eq!(
        first_sp.as_posterior().unwrap(),
        again_sp.as_posterior().unwrap(),
        "consecutive SpPar runs must be bit-identical"
    );

    let first_mp = engine.run(Algorithm::MpPar, &long).unwrap();
    let first_bs = engine.run(Algorithm::BsPar, &long).unwrap();

    // Interleave a shorter sequence (shrinks every buffer)…
    engine.run(Algorithm::SpPar, &short).unwrap();
    engine.run(Algorithm::MpPar, &short).unwrap();
    engine.run(Algorithm::BsPar, &short).unwrap();

    // …then the original input must still reproduce exactly.
    let sp = engine.run(Algorithm::SpPar, &long).unwrap();
    let mp = engine.run(Algorithm::MpPar, &long).unwrap();
    let bs = engine.run(Algorithm::BsPar, &long).unwrap();
    assert_eq!(first_sp.as_posterior().unwrap(), sp.as_posterior().unwrap());
    assert_eq!(first_mp.as_map().unwrap(), mp.as_map().unwrap());
    assert_eq!(first_bs.as_posterior().unwrap(), bs.as_posterior().unwrap());
}

#[test]
fn run_batch_matches_individual_runs() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xBA7C);
    let seqs: Vec<Vec<u32>> = [40usize, 100, 7, 256, 1]
        .iter()
        .map(|&t| sample(&hmm, t, &mut rng).observations)
        .collect();
    let engine = Engine::builder(hmm.clone())
        .scan_options(ScanOptions { threads: 4, ..ScanOptions::default() })
        .build();

    let batch = engine.run_batch(Algorithm::SpPar, &seqs);
    assert_eq!(batch.len(), seqs.len());
    for (ys, out) in seqs.iter().zip(&batch) {
        let got = out.as_ref().unwrap().as_posterior().unwrap();
        // Batch runs may use a serial per-sequence schedule; compare
        // against the library default tolerance, not bitwise.
        let want = inference::sp_seq(&hmm, ys).unwrap();
        let d = max_gamma_diff(got, &want);
        assert!(d < 1e-9, "batch T={}: max |Δγ| = {d:e}", ys.len());
    }

    // Per-item errors: an invalid sequence fails its slot only.
    let mut with_bad = seqs.clone();
    with_bad[2] = vec![0, 9, 1]; // symbol 9 out of range (M = 2)
    let batch = engine.run_batch(Algorithm::MpPar, &with_bad);
    assert!(batch[2].is_err());
    for (i, out) in batch.iter().enumerate() {
        if i != 2 {
            assert!(out.is_ok(), "slot {i} should succeed");
        }
    }

    assert!(engine.run_batch(Algorithm::SpPar, &[]).is_empty());
}

#[test]
fn output_accessors_enforce_task_shape() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut engine = Engine::builder(hmm).build();
    let post = engine.run(Algorithm::SpPar, &[0, 1, 0]).unwrap();
    assert!(post.as_posterior().is_some());
    assert!(post.as_map().is_none());
    assert!(post.clone().into_map().is_err());
    assert!(post.into_posterior().is_ok());

    let map = engine.run(Algorithm::Viterbi, &[0, 1, 0]).unwrap();
    assert!(map.as_map().is_some());
    assert!(map.clone().into_training().is_err());

    let smoothed = engine.smooth(&[0, 1, 1]).unwrap();
    assert_eq!(smoothed.len(), 3);
    let decoded = engine.decode_map(&[0, 1, 1]).unwrap();
    assert_eq!(decoded.path.len(), 3);
}

// ---------------------------------------------------------------------------
// XLA backend (stub executor — exercises lookup, marshalling, decode)
// ---------------------------------------------------------------------------

/// Fabricated artifact outputs keyed by entry family.
struct StubExec {
    gamma: Vec<f32>,
    loglik: f32,
    path: Vec<i32>,
    log_prob: f32,
}

impl ArtifactExec for StubExec {
    fn run(&self, artifact: &str, inputs: Vec<Value>) -> crate::Result<Vec<Value>> {
        // The engine must marshal the standard 5-input layout.
        assert_eq!(inputs.len(), 5);
        if artifact.starts_with("sp") {
            Ok(vec![
                Value::F32(self.gamma.clone(), vec![self.gamma.len() / 4, 4]),
                Value::scalar_f32(self.loglik),
            ])
        } else {
            Ok(vec![
                Value::I32(self.path.clone(), vec![self.path.len()]),
                Value::scalar_f32(self.log_prob),
            ])
        }
    }
}

fn stub_manifest() -> Arc<Manifest> {
    let json = r#"{
      "version": 1, "interchange": "hlo-text",
      "artifacts": [
        {"name": "sp_par_T8", "entry": "sp_par", "kind": "core",
         "t": 8, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []},
        {"name": "mp_par_T8", "entry": "mp_par", "kind": "core",
         "t": 8, "d": 4, "m": 2, "path": "a", "inputs": [], "outputs": []}
      ]
    }"#;
    Arc::new(Manifest::parse(json, PathBuf::from("/x")).unwrap())
}

fn xla_engine(stub: StubExec) -> Engine {
    let backend = XlaBackend::new(Arc::new(stub), stub_manifest());
    Engine::builder(gilbert_elliott(GeParams::default()))
        .backend(Arc::new(backend))
        .build()
}

#[test]
fn xla_backend_decodes_core_outputs() {
    let gamma: Vec<f32> = (0..32).map(|i| i as f32).collect(); // capacity 8 × D 4
    let mut engine = xla_engine(StubExec {
        gamma,
        loglik: -3.5,
        path: vec![0, 1, 2, 3, 1, 0, 0, 0],
        log_prob: -7.25,
    });
    assert_eq!(engine.backend_name(), "xla");

    // T = 5 pads into the T = 8 artifact; padding rows are discarded.
    let ys = vec![0u32, 1, 1, 0, 1];
    let post = engine.run(Algorithm::SpPar, &ys).unwrap().into_posterior().unwrap();
    assert_eq!(post.len(), 5);
    assert_eq!(post.gamma(0), &[0.0, 1.0, 2.0, 3.0]);
    assert_eq!(post.gamma(4), &[16.0, 17.0, 18.0, 19.0]);
    assert_eq!(post.log_likelihood(), -3.5);

    let est = engine.run(Algorithm::MpPar, &ys).unwrap().into_map().unwrap();
    assert_eq!(est.path, vec![0, 1, 2, 3, 1]);
    assert_eq!(est.log_prob, -7.25);

    // No artifact covers T > capacity, sequential entries, or training.
    assert!(engine.run(Algorithm::SpPar, &vec![0u32; 9]).is_err());
    assert!(engine.run(Algorithm::SpSeq, &ys).is_err());
    assert!(engine.run(Algorithm::BaumWelch, &ys).is_err());
}

#[test]
fn xla_backend_rejects_out_of_range_states() {
    let mut engine = xla_engine(StubExec {
        gamma: vec![0.0; 32],
        loglik: 0.0,
        path: vec![0, 1, 9, 0, 0, 0, 0, 0], // state 9 ≥ D = 4
        log_prob: 0.0,
    });
    let err = engine.run(Algorithm::MpPar, &[0, 1, 1]).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
}

#[test]
fn explicit_native_backend_matches_default() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut a = Engine::builder(hmm.clone()).build();
    let mut b = Engine::builder(hmm)
        .backend(Arc::new(NativeBackend))
        .build();
    let ys = vec![0u32, 1, 0, 1, 1, 0];
    let pa = a.run(Algorithm::SpPar, &ys).unwrap();
    let pb = b.run(Algorithm::SpPar, &ys).unwrap();
    assert_eq!(pa.as_posterior().unwrap(), pb.as_posterior().unwrap());
    match a.run(Algorithm::BaumWelch, &ys).unwrap() {
        EngineOutput::Training(res) => assert!(res.iterations > 0),
        other => panic!("expected training output, got {other:?}"),
    }
}

//! Declarative CLI argument parser (clap is unavailable offline —
//! DESIGN.md §1).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options with defaults, and positional arguments; generates usage
//! text. Just enough structure for `hmm-scan`'s command surface, fully
//! unit-tested.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// An option specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name (without the `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// None → boolean flag; Some(default) → value option.
    pub default: Option<&'static str>,
}

/// A subcommand specification.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Accepted options/flags.
    pub opts: Vec<OptSpec>,
    /// Names of accepted positional arguments (usage text).
    pub positional: Vec<&'static str>,
}

/// Parsed invocation.
#[derive(Debug, Clone)]
pub struct Parsed {
    /// The matched subcommand.
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments, as given.
    pub positional: Vec<String>,
}

impl Parsed {
    /// An option's value (its default when not supplied).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// An option's value parsed as usize, with a typed usage error.
    pub fn get_usize(&self, key: &str) -> Result<usize> {
        let v = self
            .get(key)
            .ok_or_else(|| Error::usage(format!("missing --{key}")))?;
        v.parse()
            .map_err(|_| Error::usage(format!("--{key}: '{v}' is not an integer")))
    }

    /// An option's value parsed as f64, with a typed usage error.
    pub fn get_f64(&self, key: &str) -> Result<f64> {
        let v = self
            .get(key)
            .ok_or_else(|| Error::usage(format!("missing --{key}")))?;
        v.parse()
            .map_err(|_| Error::usage(format!("--{key}: '{v}' is not a number")))
    }

    /// Whether a boolean flag was supplied.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// The application CLI: subcommands + global help.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    /// Program name (usage text).
    pub app: &'static str,
    /// One-line program description.
    pub about: &'static str,
    /// Registered subcommands.
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    /// A CLI with no commands yet.
    pub fn new(app: &'static str, about: &'static str) -> Self {
        Self { app, about, commands: Vec::new() }
    }

    /// Register a subcommand (builder-style).
    pub fn command(
        mut self,
        name: &'static str,
        help: &'static str,
        opts: Vec<OptSpec>,
        positional: Vec<&'static str>,
    ) -> Self {
        self.commands.push(CommandSpec { name, help, opts, positional });
        self
    }

    /// Parse argv (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let Some(cmd_name) = args.first() else {
            return Err(Error::usage(self.usage()));
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(Error::usage(self.usage()));
        }
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| {
                Error::usage(format!("unknown command '{cmd_name}'\n\n{}", self.usage()))
            })?;

        let mut values = BTreeMap::new();
        for opt in &spec.opts {
            if let Some(d) = opt.default {
                values.insert(opt.name.to_string(), d.to_string());
            }
        }
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let arg = &args[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                if key == "help" {
                    return Err(Error::usage(self.command_usage(spec)));
                }
                let opt = spec.opts.iter().find(|o| o.name == key).ok_or_else(|| {
                    Error::usage(format!(
                        "unknown option '--{key}' for '{}'\n\n{}",
                        spec.name,
                        self.command_usage(spec)
                    ))
                })?;
                match (&opt.default, inline_val) {
                    (None, None) => flags.push(key.to_string()),
                    (None, Some(_)) => {
                        return Err(Error::usage(format!("--{key} takes no value")))
                    }
                    (Some(_), Some(v)) => {
                        values.insert(key.to_string(), v);
                    }
                    (Some(_), None) => {
                        i += 1;
                        let v = args.get(i).ok_or_else(|| {
                            Error::usage(format!("--{key} requires a value"))
                        })?;
                        values.insert(key.to_string(), v.clone());
                    }
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        if positional.len() > spec.positional.len() {
            return Err(Error::usage(format!(
                "too many positional arguments for '{}'",
                spec.name
            )));
        }
        Ok(Parsed { command: spec.name.to_string(), values, flags, positional })
    }

    /// Top-level usage text (program + command list).
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nCommands:\n", self.app, self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:<10} {}\n", c.name, c.help));
        }
        out.push_str(&format!(
            "\nRun `{} <command> --help` for command options.\n",
            self.app
        ));
        out
    }

    fn command_usage(&self, spec: &CommandSpec) -> String {
        let mut out = format!("{} {} — {}\n", self.app, spec.name, spec.help);
        if !spec.positional.is_empty() {
            out.push_str(&format!("positional: {}\n", spec.positional.join(" ")));
        }
        if !spec.opts.is_empty() {
            out.push_str("options:\n");
            for o in &spec.opts {
                match o.default {
                    Some(d) => out.push_str(&format!(
                        "  --{:<14} {} [default: {d}]\n",
                        o.name, o.help
                    )),
                    None => out.push_str(&format!("  --{:<14} {}\n", o.name, o.help)),
                }
            }
        }
        out
    }
}

/// Shorthand for a value option with a default.
pub fn opt(name: &'static str, help: &'static str, default: &'static str) -> OptSpec {
    OptSpec { name, help, default: Some(default) }
}

/// Shorthand for a boolean flag.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("hmm-scan", "test").command(
            "bench",
            "run benches",
            vec![
                opt("t", "sequence length", "1024"),
                opt("out", "output dir", "results"),
                flag("verbose", "print more"),
            ],
            vec!["target"],
        )
    }

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let p = cli().parse(&args("bench")).unwrap();
        assert_eq!(p.command, "bench");
        assert_eq!(p.get_usize("t").unwrap(), 1024);
        assert!(!p.flag("verbose"));

        let p = cli().parse(&args("bench --t 99 --verbose fig3")).unwrap();
        assert_eq!(p.get_usize("t").unwrap(), 99);
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["fig3"]);

        let p = cli().parse(&args("bench --t=7")).unwrap();
        assert_eq!(p.get_usize("t").unwrap(), 7);
    }

    #[test]
    fn rejects_errors() {
        assert!(cli().parse(&args("")).is_err());
        assert!(cli().parse(&args("nope")).is_err());
        assert!(cli().parse(&args("bench --bogus 1")).is_err());
        assert!(cli().parse(&args("bench --t")).is_err());
        assert!(cli().parse(&args("bench --verbose=1")).is_err());
        assert!(cli().parse(&args("bench a b")).is_err());
        assert!(cli().parse(&args("bench --t abc")).unwrap().get_usize("t").is_err());
    }

    #[test]
    fn help_is_usage_error_with_text() {
        let err = cli().parse(&args("--help")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("Commands"));
        let err = cli().parse(&args("bench --help")).unwrap_err();
        assert!(err.to_string().contains("--t"));
    }

    #[test]
    fn numbers_and_floats() {
        let c = Cli::new("x", "y").command(
            "run",
            "",
            vec![opt("rate", "", "0.5")],
            vec![],
        );
        let p = c.parse(&args("run --rate 0.25")).unwrap();
        assert_eq!(p.get_f64("rate").unwrap(), 0.25);
    }
}

//! Bench target: the network serving layer — sustained decode
//! throughput and wire latency over loopback TCP, swept across
//! concurrent connections × pipelining depth.
//!
//! Each cell starts a fresh `NetServer` over a native coordinator,
//! spawns `conns` client threads, and keeps `pipeline` decode requests
//! in flight per connection (send → match response by id). Reported per
//! cell: sustained req/s and p50/p99/max request latency (send to
//! response, including queueing behind the pipeline).
//!
//! The acceptance row: ≥ 4 concurrent pipelined connections must be
//! measured (the fleet shape the coordinator's worker pools are sized
//! for). `HMM_SCAN_BENCH_SMOKE=1` shrinks the sweep to a CI smoke run.
//!
//! Besides the text table, every cell lands as a row in the `"net"`
//! section of `BENCH_net.json` (shared with `bench-cluster` through
//! `benchx::merge_bench_json`) so trend tooling never parses stdout.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hmm_scan::coordinator::{Algo, Coordinator, CoordinatorConfig, DecodeRequest};
use hmm_scan::jsonx::Json;
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::net::{NetClient, NetServer, NetServerConfig};
use hmm_scan::rng::Xoshiro256StarStar;

fn pct_us(sorted: &[Duration], p: f64) -> u128 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
    sorted[idx].as_micros()
}

/// One sweep cell: `conns` connections × `pipeline` in-flight each,
/// `requests` decodes per connection of length `t`. Returns
/// (total served, wall, sorted latencies).
fn run_cell(
    addr: &str,
    conns: usize,
    pipeline: usize,
    requests: usize,
    t: usize,
) -> (usize, Duration, Vec<Duration>) {
    let hmm = gilbert_elliott(GeParams::default());
    let t0 = Instant::now();
    let mut all: Vec<Duration> = Vec::new();
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..conns {
            let hmm = hmm.clone();
            joins.push(scope.spawn(move || {
                let mut client =
                    NetClient::connect(addr).expect("bench client connect");
                let mut rng =
                    Xoshiro256StarStar::seed_from_u64(0xBE7 + c as u64);
                let reqs: Vec<DecodeRequest> = (0..requests)
                    .map(|i| {
                        let ys = sample(&hmm, t, &mut rng).observations;
                        let algo =
                            if i % 2 == 0 { Algo::Smooth } else { Algo::Map };
                        DecodeRequest::new(i as u64, "ge", ys, algo)
                    })
                    .collect();
                client
                    .pipeline_decodes(reqs, pipeline)
                    .expect("pipelined decode failed")
            }));
        }
        for join in joins {
            all.extend(join.join().expect("bench thread panicked"));
        }
    });
    let wall = t0.elapsed();
    all.sort_unstable();
    (conns * requests, wall, all)
}

fn main() {
    let smoke = std::env::var("HMM_SCAN_BENCH_SMOKE").as_deref() == Ok("1");
    let (conn_grid, pipe_grid, requests, t): (&[usize], &[usize], usize, usize) =
        if smoke {
            (&[4], &[1, 8], 24, 256)
        } else {
            (&[1, 4, 8], &[1, 8, 32], 128, 512)
        };

    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig::native_only())
            .expect("bench coordinator"),
    );
    coord.register_model("ge", gilbert_elliott(GeParams::default()));
    let server = NetServer::start(
        Arc::clone(&coord),
        "127.0.0.1:0",
        NetServerConfig {
            max_connections: conn_grid.iter().copied().max().unwrap_or(8) + 4,
            max_inflight_per_conn: pipe_grid.iter().copied().max().unwrap_or(32),
            exec_threads: hmm_scan::exec::default_parallelism().min(8),
            ..NetServerConfig::default()
        },
    )
    .expect("bench server");
    let addr = server.local_addr().to_string();
    println!(
        "net bench on {addr} (T={t}, {requests} reqs/conn; latency includes \
         pipeline queueing)"
    );
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10}",
        "conns x pipeline", "req/s", "p50", "p99", "max"
    );

    let mut measured_4plus_pipelined = false;
    let mut rows: Vec<Json> = Vec::new();
    for &conns in conn_grid {
        for &pipeline in pipe_grid {
            let (served, wall, lat) =
                run_cell(&addr, conns, pipeline, requests, t);
            let req_per_s = served as f64 / wall.as_secs_f64();
            let (p50, p99) = (pct_us(&lat, 0.50), pct_us(&lat, 0.99));
            let max = lat.last().map_or(0, |d| d.as_micros());
            println!(
                "{:<22} {:>10.1} {:>9}µ {:>9}µ {:>9}µ",
                format!("{conns} x {pipeline}"),
                req_per_s,
                p50,
                p99,
                max,
            );
            let mut row = BTreeMap::new();
            row.insert("conns".to_string(), Json::Num(conns as f64));
            row.insert("pipeline".to_string(), Json::Num(pipeline as f64));
            row.insert("t".to_string(), Json::Num(t as f64));
            row.insert("requests".to_string(), Json::Num(served as f64));
            row.insert("req_per_s".to_string(), Json::Num(req_per_s));
            row.insert("p50_us".to_string(), Json::Num(p50 as f64));
            row.insert("p99_us".to_string(), Json::Num(p99 as f64));
            row.insert("max_us".to_string(), Json::Num(max as f64));
            rows.push(Json::Obj(row));
            if conns >= 4 && pipeline > 1 {
                measured_4plus_pipelined = true;
            }
        }
    }
    let report = std::path::Path::new("BENCH_net.json");
    hmm_scan::benchx::merge_bench_json(report, "net", rows)
        .expect("write BENCH_net.json");
    println!("\nwrote {} rows to {}", conn_grid.len() * pipe_grid.len(), report.display());
    assert!(
        measured_4plus_pipelined,
        "the sweep must cover ≥4 concurrent pipelined connections"
    );

    let graceful = server.shutdown(Duration::from_secs(10));
    let snap = coord.metrics().snapshot();
    println!(
        "\nserver: {} conns served, {} wire decodes, drain {}",
        snap.conns_opened,
        snap.wire_verbs
            .iter()
            .find(|v| v.verb == "decode")
            .map_or(0, |v| v.count),
        if graceful { "graceful" } else { "forced" },
    );
    assert_eq!(snap.failed, 0, "no request may fail under the sweep");
}

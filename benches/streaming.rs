//! Bench target: the streaming session hot path — per-append cost vs the
//! full-recompute baseline a complete-sequence API forces on streaming
//! clients, fixed-lag query latency, and the session store's
//! spill/restore costs (the eviction tax).
//!
//! The acceptance claim: appending k observations to a T-long session
//! costs O(k + B) (checkpointed scan), so the `session_append` rows stay
//! ~flat as T grows while `full_recompute` rows grow linearly —
//! sublinear per-append cost at T ≥ 4096. `store_spill` /
//! `store_restore` rows track what demoting/promoting a T-long session
//! to/from the disk log costs (O(T) serde, ~half the combines skipped on
//! restore thanks to the checkpoint summaries).
//!
//! `HMM_SCAN_BENCH_SMOKE=1` shrinks the grid and time budget to a CI
//! smoke run (a few seconds total).

use std::time::Duration;

use hmm_scan::benchx::{bench, black_box, format_table, BenchConfig};
use hmm_scan::engine::{Algorithm, Engine, SessionOptions};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::scan::ScanOptions;
use hmm_scan::store::{DiskStore, SessionMeta, SessionStore};

fn main() {
    let smoke = std::env::var("HMM_SCAN_BENCH_SMOKE").as_deref() == Ok("1");
    let grid: &[usize] = if smoke {
        &[4096]
    } else {
        &[4096, 16384, 65536]
    };
    let cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            time_budget: Duration::from_millis(100),
        }
    } else {
        BenchConfig::default()
    };

    let hmm = gilbert_elliott(GeParams::default());
    let opts = ScanOptions::default().with_block(256);
    let append = 16usize; // observations per arrival
    let lag = 64usize;
    let mut rows = Vec::new();

    let store_dir = std::env::temp_dir()
        .join(format!("hmm-scan-bench-store-{}", std::process::id()));
    let store = DiskStore::open(&store_dir).expect("open bench store");

    for &t in grid {
        let mut rng = Xoshiro256StarStar::seed_from_u64(9);
        let ys = sample(&hmm, t + append, &mut rng).observations;
        let engine = Engine::builder(hmm.clone()).scan_options(opts).build();

        // Steady-state append: session pre-filled to T; each iteration
        // appends k observations and reads the filtering marginal. The
        // session keeps growing across iterations, which only biases
        // *against* the streamed row — append cost is length-invariant.
        let mut session = engine.open_session(SessionOptions::default());
        session.push(&ys[..t]).unwrap();
        let chunk = &ys[t..];
        rows.push(bench(&format!("session_append{append}/T={t}"), cfg, || {
            session.push(black_box(chunk)).unwrap();
            session.filtered().unwrap().log_likelihood
        }));

        rows.push(bench(&format!("session_lag{lag}/T={t}"), cfg, || {
            session.smoothed_lag(black_box(lag)).unwrap().posterior.len()
        }));

        // Baseline: what a complete-sequence API costs per arrival —
        // rerun the full parallel smoother on all T observations.
        let mut full = Engine::builder(hmm.clone()).scan_options(opts).build();
        rows.push(bench(&format!("full_recompute/T={t}"), cfg, || {
            full.run(Algorithm::SpPar, black_box(&ys[..t]))
                .unwrap()
                .into_posterior()
                .unwrap()
                .log_likelihood()
        }));

        // The exact-finish path for scale: checkpointed forward
        // materialization + full backward scan (≈ half the forward
        // combines of the cold run above).
        let mut fin = engine.open_session(SessionOptions::default());
        fin.push(&ys[..t]).unwrap();
        rows.push(bench(&format!("session_finish/T={t}"), cfg, || {
            fin.finish().unwrap().log_likelihood()
        }));

        // Session-store eviction tax: spill = snapshot + compacted log
        // rewrite; restore = log read + checkpoint resume + replay.
        let id = t as u64;
        let meta = SessionMeta {
            model: "ge".to_string(),
            options: SessionOptions::default(),
            lag: 0,
            fingerprint: None,
        };
        store.create(id, &meta).unwrap();
        let mut cold = engine.open_session(SessionOptions::default());
        cold.push(&ys[..t]).unwrap();
        rows.push(bench(&format!("store_spill/T={t}"), cfg, || {
            store.compact(id, &meta, &cold.snapshot()).unwrap();
            cold.len()
        }));
        store.compact(id, &meta, &cold.snapshot()).unwrap();
        // A few post-checkpoint appends so the restore row includes the
        // append-replay cost — the variable part compaction bounds.
        for chunk in ys[t..].chunks(4) {
            store.log_append(id, chunk).unwrap();
        }
        rows.push(bench(&format!("store_restore/T={t}"), cfg, || {
            let stored = store.restore(id).unwrap();
            let mut s = engine
                .resume_session(stored.snapshot.as_ref().unwrap())
                .unwrap();
            for chunk in &stored.appends {
                s.push(chunk).unwrap();
            }
            s.len()
        }));
        store.remove(id).unwrap();
    }

    std::fs::remove_dir_all(&store_dir).ok();
    println!("{}", format_table(&rows));
    println!(
        "(session_append rows should stay ~flat in T; full_recompute grows \
         linearly — the streaming win. store_spill/store_restore are the \
         per-eviction tax the coordinator pays past its resident watermark.)"
    );
}

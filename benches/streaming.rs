//! Bench target: the streaming session hot path — per-append cost vs the
//! full-recompute baseline a complete-sequence API forces on streaming
//! clients, fixed-lag query latency, and the session store's
//! spill/restore costs (the eviction tax).
//!
//! The acceptance claim: appending k observations to a T-long session
//! costs O(k + B) (checkpointed scan), so the `session_append` rows stay
//! ~flat as T grows while `full_recompute` rows grow linearly —
//! sublinear per-append cost at T ≥ 4096. `store_spill` /
//! `store_restore` rows track what demoting/promoting a T-long session
//! to/from the disk log costs (O(T) serde, ~half the combines skipped on
//! restore thanks to the checkpoint summaries).
//!
//! Three store-scalability sections follow the per-append rows:
//!
//! * **housekeeping burst** — p50/p99 append latency while watermark
//!   spills are due on every append: in-band (`housekeeping: false`)
//!   each append pays a fat session's snapshot+rewrite inline, so p99
//!   spikes; with the background worker the same appends stay flat.
//! * **group commit** — fsync accounting for 1k appends across 32
//!   concurrent sessions: a zero window pays one fsync barrier per
//!   append; the deadline window batches them into ~appends/sessions
//!   sync points (per-*file* fsyncs are floor-bounded at one per dirty
//!   log per window — the barrier count is what amortizes).
//! * **recovery scan** — byte-read counters for metadata-only recovery
//!   (`recover_meta`, frame headers only) vs the full log parse.
//!
//! A final **timeline overhead** section measures the append hot path
//! with coordinator event recording on vs off — recording is one
//! bounded-channel send per append, so the p50 must stay within 5% of
//! the timeline-off baseline (the observability tier's overhead claim).
//! A **tracing overhead** section makes the same claim for request
//! spans: a loopback wire decode with span emission on (server
//! timeline configured, every request trace-stamped) vs off, p50
//! within 5%; both rows land in the `"tracing"` section of
//! `BENCH_net.json`.
//!
//! `HMM_SCAN_BENCH_SMOKE=1` shrinks the grid and time budget to a CI
//! smoke run (a few seconds total).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hmm_scan::benchx::{bench, black_box, fmt_duration, format_table, BenchConfig};
use hmm_scan::coordinator::{
    Algo, Coordinator, CoordinatorConfig, DecodeRequest, StreamReply,
    StreamRequest,
};
use hmm_scan::elements::serde::to_decimal_json;
use hmm_scan::engine::{Algorithm, Engine, SessionOptions};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::jsonx::Json;
use hmm_scan::net::{NetClient, NetServer, NetServerConfig};
use hmm_scan::obs::Timeline;
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::scan::ScanOptions;
use hmm_scan::store::{
    DiskStore, SessionMeta, SessionStore, DEFAULT_GROUP_COMMIT_WINDOW,
};

fn pct(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn bench_meta() -> SessionMeta {
    SessionMeta {
        model: "ge".to_string(),
        options: SessionOptions::default(),
        lag: 0,
        fingerprint: None,
    }
}

/// Round-robin appends over fat sessions at watermark 4: every append
/// restores an evicted session, so a spill of another fat session is
/// due each time. Returns (p50, p99, spills) of the append latency —
/// in-band mode pays the spill inside the append, housekeeping mode
/// backgrounds it.
fn burst_append_latency(
    housekeeping: bool,
    smoke: bool,
) -> (Duration, Duration, u64) {
    let hmm = gilbert_elliott(GeParams::default());
    let dir = std::env::temp_dir().join(format!(
        "hmm-scan-bench-hk{}-{}",
        housekeeping as u8,
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let sessions = if smoke { 6 } else { 8 };
    let prefill = if smoke { 400 } else { 3000 };
    let rounds = if smoke { 4 } else { 30 };
    let coord = Coordinator::new(CoordinatorConfig {
        resident_watermark: 4,
        session_store: Some(dir.clone()),
        // Isolate the spill cost: no periodic compaction interference,
        // and no group-commit window flooring every append the same way
        // in both modes (the window has its own section below).
        checkpoint_every: 1 << 30,
        group_commit_window: Duration::ZERO,
        housekeeping,
        ..CoordinatorConfig::native_only()
    })
    .expect("bench coordinator");
    coord.register_model("ge", hmm.clone());
    let mut rng = Xoshiro256StarStar::seed_from_u64(11);
    let mut ids = Vec::new();
    for i in 0..sessions {
        let r = coord
            .stream(StreamRequest::open(i as u64, "ge", 0))
            .expect("open");
        let StreamReply::Opened { session } = r.reply else { unreachable!() };
        // Fat prefill — the snapshot volume every spill must serialize.
        let chunk = sample(&hmm, prefill, &mut rng).observations;
        coord.stream(StreamRequest::append(0, session, chunk)).expect("prefill");
        ids.push(session);
    }
    let mut lat = Vec::new();
    for _ in 0..rounds {
        for &id in &ids {
            let chunk = sample(&hmm, 8, &mut rng).observations;
            let t0 = Instant::now();
            coord.stream(StreamRequest::append(1, id, chunk)).expect("append");
            lat.push(t0.elapsed());
        }
    }
    coord.quiesce_housekeeping();
    let spills = coord.metrics().snapshot().spills;
    lat.sort_unstable();
    let out = (pct(&lat, 0.50), pct(&lat, 0.99), spills);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

/// Drive `appends` log appends across `sessions` concurrent sessions
/// and return (fsync syscalls, group sync points, wall time).
fn sync_amortization(
    window: Duration,
    sessions: usize,
    appends: usize,
) -> (u64, u64, Duration) {
    let dir = std::env::temp_dir().join(format!(
        "hmm-scan-bench-gc{}-{}",
        window.as_micros(),
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        DiskStore::open(&dir)
            .expect("open bench store")
            .with_group_commit_window(window),
    );
    let meta = bench_meta();
    for id in 0..sessions as u64 {
        store.create(id, &meta).expect("create");
    }
    let per = appends / sessions;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for id in 0..sessions as u64 {
            let store = Arc::clone(&store);
            scope.spawn(move || {
                for k in 0..per {
                    store
                        .log_append(id, &[(k % 2) as u32, 1, 0])
                        .expect("append");
                }
            });
        }
    });
    let wall = t0.elapsed();
    let out = (store.log_syncs(), store.sync_batches(), wall);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

/// Build a store with `sessions` fat logs, then compare the byte-read
/// cost of metadata-only recovery against the full parse.
fn recovery_scan_cost(
    sessions: usize,
    chunks: usize,
    chunk_len: usize,
) -> (u64, u64, u64, Duration, Duration) {
    let dir = std::env::temp_dir()
        .join(format!("hmm-scan-bench-rec-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = DiskStore::open(&dir).expect("open bench store");
    let meta = bench_meta();
    let chunk: Vec<u32> = (0..chunk_len as u32).map(|k| k % 2).collect();
    let mut stored_bytes = 0u64;
    for id in 0..sessions as u64 {
        store.create(id, &meta).expect("create");
        for _ in 0..chunks {
            store.log_append(id, &chunk).expect("append");
        }
        // Small tail record: the metadata scan's backwards validation
        // reads the last payload, and the point of the comparison is
        // that it reads nothing else.
        store.log_append(id, &[0, 1, 1]).expect("append");
        stored_bytes += std::fs::metadata(store.path_for(id))
            .map(|m| m.len())
            .unwrap_or(0);
    }
    let before = store.bytes_read();
    let t0 = Instant::now();
    let metas = store.recover_meta().expect("recover_meta");
    let meta_wall = t0.elapsed();
    let meta_bytes = store.bytes_read() - before;
    assert_eq!(metas.len(), sessions);

    let before = store.bytes_read();
    let t0 = Instant::now();
    let full = store.recover().expect("recover");
    let full_wall = t0.elapsed();
    let full_bytes = store.bytes_read() - before;
    assert_eq!(full.len(), sessions);
    for ((_, _, len), (_, s)) in metas.iter().zip(full.iter()) {
        assert_eq!(*len, s.len(), "metadata scan disagrees with full parse");
    }
    let _ = std::fs::remove_dir_all(&dir);
    (stored_bytes, meta_bytes, full_bytes, meta_wall, full_wall)
}

/// Median append latency with the event timeline enabled or not — the
/// cost of one bounded-channel send (event rendered writer-side) on the
/// coordinator's append hot path. No store, so appends never spill:
/// the delta is the recording itself, not housekeeping noise.
fn timeline_append_p50(with_timeline: bool, smoke: bool) -> Duration {
    let hmm = gilbert_elliott(GeParams::default());
    let dir = std::env::temp_dir().join(format!(
        "hmm-scan-bench-tl{}-{}",
        with_timeline as u8,
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let timeline = if with_timeline {
        Some(Timeline::open(&dir).expect("bench timeline"))
    } else {
        None
    };
    let coord = Coordinator::new(CoordinatorConfig {
        timeline: timeline.clone(),
        ..CoordinatorConfig::native_only()
    })
    .expect("bench coordinator");
    coord.register_model("ge", hmm.clone());
    let mut rng = Xoshiro256StarStar::seed_from_u64(17);
    let r = coord.stream(StreamRequest::open(0, "ge", 0)).expect("open");
    let StreamReply::Opened { session } = r.reply else { unreachable!() };
    let rounds = if smoke { 200 } else { 4000 };
    let mut lat = Vec::with_capacity(rounds);
    for seq in 0..rounds {
        let chunk = sample(&hmm, 8, &mut rng).observations;
        let t0 = Instant::now();
        coord
            .stream(StreamRequest::append(seq as u64 + 1, session, chunk))
            .expect("append");
        lat.push(t0.elapsed());
    }
    if let Some(tl) = &timeline {
        tl.flush();
    }
    drop(coord);
    lat.sort_unstable();
    let p50 = pct(&lat, 0.50);
    let _ = std::fs::remove_dir_all(&dir);
    p50
}

/// Median loopback wire-decode latency with request tracing on or off.
/// The client stamps a trace context on every request either way (wire
/// v4 is additive); a server without a timeline drops it on the floor,
/// so the delta is span emission itself — the begin/end records the
/// execute stage adds to the decode hot path.
fn traced_decode_p50(with_tracing: bool, smoke: bool) -> Duration {
    let hmm = gilbert_elliott(GeParams::default());
    let dir = std::env::temp_dir().join(format!(
        "hmm-scan-bench-tr{}-{}",
        with_tracing as u8,
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let timeline = if with_tracing {
        Some(Timeline::open(&dir).expect("bench timeline"))
    } else {
        None
    };
    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig {
            timeline: timeline.clone(),
            ..CoordinatorConfig::native_only()
        })
        .expect("bench coordinator"),
    );
    coord.register_model("ge", hmm.clone());
    let server = NetServer::start(
        Arc::clone(&coord),
        "127.0.0.1:0",
        NetServerConfig {
            timeline: timeline.clone(),
            ..NetServerConfig::default()
        },
    )
    .expect("bench server");
    let addr = server.local_addr().to_string();
    let mut client = NetClient::connect(&addr).expect("bench client");
    let mut rng = Xoshiro256StarStar::seed_from_u64(19);
    let t = 256;
    let rounds = if smoke { 60 } else { 1500 };
    let mut lat = Vec::with_capacity(rounds);
    for i in 0..rounds {
        let ys = sample(&hmm, t, &mut rng).observations;
        let req = DecodeRequest::new(i as u64, "ge", ys, Algo::Smooth);
        let t0 = Instant::now();
        client.decode(&req).expect("decode");
        lat.push(t0.elapsed());
    }
    drop(client);
    server.shutdown(Duration::from_secs(10));
    if let Some(tl) = &timeline {
        tl.flush();
    }
    lat.sort_unstable();
    let p50 = pct(&lat, 0.50);
    let _ = std::fs::remove_dir_all(&dir);
    p50
}

fn main() {
    let smoke = std::env::var("HMM_SCAN_BENCH_SMOKE").as_deref() == Ok("1");
    let grid: &[usize] = if smoke {
        &[4096]
    } else {
        &[4096, 16384, 65536]
    };
    let cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            time_budget: Duration::from_millis(100),
        }
    } else {
        BenchConfig::default()
    };

    let hmm = gilbert_elliott(GeParams::default());
    let opts = ScanOptions::default().with_block(256);
    let append = 16usize; // observations per arrival
    let lag = 64usize;
    let mut rows = Vec::new();

    let store_dir = std::env::temp_dir()
        .join(format!("hmm-scan-bench-store-{}", std::process::id()));
    let store = DiskStore::open(&store_dir).expect("open bench store");

    for &t in grid {
        let mut rng = Xoshiro256StarStar::seed_from_u64(9);
        let ys = sample(&hmm, t + append, &mut rng).observations;
        let engine = Engine::builder(hmm.clone()).scan_options(opts).build();

        // Steady-state append: session pre-filled to T; each iteration
        // appends k observations and reads the filtering marginal. The
        // session keeps growing across iterations, which only biases
        // *against* the streamed row — append cost is length-invariant.
        let mut session = engine.open_session(SessionOptions::default());
        session.push(&ys[..t]).unwrap();
        let chunk = &ys[t..];
        rows.push(bench(&format!("session_append{append}/T={t}"), cfg, || {
            session.push(black_box(chunk)).unwrap();
            session.filtered().unwrap().log_likelihood
        }));

        rows.push(bench(&format!("session_lag{lag}/T={t}"), cfg, || {
            session.smoothed_lag(black_box(lag)).unwrap().posterior.len()
        }));

        // Baseline: what a complete-sequence API costs per arrival —
        // rerun the full parallel smoother on all T observations.
        let mut full = Engine::builder(hmm.clone()).scan_options(opts).build();
        rows.push(bench(&format!("full_recompute/T={t}"), cfg, || {
            full.run(Algorithm::SpPar, black_box(&ys[..t]))
                .unwrap()
                .into_posterior()
                .unwrap()
                .log_likelihood()
        }));

        // The exact-finish path for scale: checkpointed forward
        // materialization + full backward scan (≈ half the forward
        // combines of the cold run above).
        let mut fin = engine.open_session(SessionOptions::default());
        fin.push(&ys[..t]).unwrap();
        rows.push(bench(&format!("session_finish/T={t}"), cfg, || {
            fin.finish().unwrap().log_likelihood()
        }));

        // Session-store eviction tax: spill = snapshot + compacted log
        // rewrite; restore = log read + checkpoint resume + replay.
        let id = t as u64;
        let meta = SessionMeta {
            model: "ge".to_string(),
            options: SessionOptions::default(),
            lag: 0,
            fingerprint: None,
        };
        store.create(id, &meta).unwrap();
        let mut cold = engine.open_session(SessionOptions::default());
        cold.push(&ys[..t]).unwrap();
        rows.push(bench(&format!("store_spill/T={t}"), cfg, || {
            store.compact(id, &meta, &cold.snapshot()).unwrap();
            cold.len()
        }));
        store.compact(id, &meta, &cold.snapshot()).unwrap();
        // A few post-checkpoint appends so the restore row includes the
        // append-replay cost — the variable part compaction bounds.
        for chunk in ys[t..].chunks(4) {
            store.log_append(id, chunk).unwrap();
        }
        rows.push(bench(&format!("store_restore/T={t}"), cfg, || {
            let stored = store.restore(id).unwrap();
            let mut s = engine
                .resume_session(stored.snapshot.as_ref().unwrap())
                .unwrap();
            for chunk in &stored.appends {
                s.push(chunk).unwrap();
            }
            s.len()
        }));
        store.remove(id).unwrap();
    }

    std::fs::remove_dir_all(&store_dir).ok();
    println!("{}", format_table(&rows));
    println!(
        "(session_append rows should stay ~flat in T; full_recompute grows \
         linearly — the streaming win. store_spill/store_restore are the \
         per-eviction tax the coordinator pays past its resident watermark.)"
    );

    // ---- housekeeping: spill cost in-band vs backgrounded -------------
    let (p50_off, p99_off, spills_off) = burst_append_latency(false, smoke);
    let (p50_on, p99_on, spills_on) = burst_append_latency(true, smoke);
    println!("\nhousekeeping burst (watermark 4, fat spill due every append):");
    println!(
        "  hk=off  append p50 {:>9}  p99 {:>9}   ({spills_off} spills, \
         in-band)",
        fmt_duration(p50_off),
        fmt_duration(p99_off),
    );
    println!(
        "  hk=on   append p50 {:>9}  p99 {:>9}   ({spills_on} spills, \
         backgrounded)",
        fmt_duration(p50_on),
        fmt_duration(p99_on),
    );
    println!(
        "  (p99 ratio {:.1}×: with housekeeping on, the append path never \
         serializes a fat snapshot)",
        p99_off.as_secs_f64() / p99_on.as_secs_f64().max(1e-9),
    );

    // ---- group commit: sync accounting per 1k appends -----------------
    let gc_sessions = if smoke { 8 } else { 32 };
    let gc_appends = if smoke { 128 } else { 1024 };
    let (syncs_0, points_0, wall_0) =
        sync_amortization(Duration::ZERO, gc_sessions, gc_appends);
    let (syncs_w, points_w, wall_w) =
        sync_amortization(DEFAULT_GROUP_COMMIT_WINDOW, gc_sessions, gc_appends);
    let per_1k = |n: u64| n * 1000 / gc_appends as u64;
    println!(
        "\ngroup commit ({gc_sessions} concurrent sessions, {gc_appends} \
         appends):"
    );
    println!(
        "  window=0     fsyncs/1k {:>5}  sync points/1k {:>5}  wall {}",
        per_1k(syncs_0),
        per_1k(points_0),
        fmt_duration(wall_0),
    );
    println!(
        "  window={:>3}µs fsyncs/1k {:>5}  sync points/1k {:>5}  wall {}",
        DEFAULT_GROUP_COMMIT_WINDOW.as_micros(),
        per_1k(syncs_w),
        per_1k(points_w),
        fmt_duration(wall_w),
    );
    let drop = points_0 as f64 / points_w.max(1) as f64;
    println!(
        "  (sync count per 1k appends drops {drop:.1}× — every append in a \
         window shares one sync point instead of paying its own fsync \
         barrier; per-file fsyncs stay floor-bounded at one per dirty log \
         per window)"
    );
    assert!(
        drop >= 5.0 || smoke,
        "group commit batched only {drop:.1}× at {gc_sessions} sessions"
    );

    // ---- recovery: metadata-only scan vs full parse -------------------
    // Chunk sizes keep packed (v3) bodies well above the header bytes
    // the metadata scan reads.
    let (rec_sessions, rec_chunks, rec_len) =
        if smoke { (16, 8, 4096) } else { (64, 16, 8192) };
    let (stored, meta_bytes, full_bytes, meta_wall, full_wall) =
        recovery_scan_cost(rec_sessions, rec_chunks, rec_len);
    println!(
        "\nrecovery scan ({rec_sessions} sessions, {} stored bytes):",
        stored
    );
    println!(
        "  recover_meta  read {:>9} bytes  in {:>9}   (frame headers only)",
        meta_bytes,
        fmt_duration(meta_wall),
    );
    println!(
        "  recover       read {:>9} bytes  in {:>9}   (full log parse)",
        full_bytes,
        fmt_duration(full_wall),
    );
    assert!(
        meta_bytes * 5 < full_bytes,
        "metadata-only recovery read {meta_bytes} of {full_bytes} parsed \
         bytes — that is a body read, not a header walk"
    );

    // ---- snapshot compression: packed (v3) vs decimal (v2) logs -------
    // The same checkpoint, written twice: once with the packed hex
    // payloads every writer emits now, once rewritten to the v2-era
    // decimal arrays — the log-size claim behind the store-format v3
    // bump (docs/STORE_FORMAT.md).
    let t_ckpt = *grid.last().unwrap();
    let dir = std::env::temp_dir()
        .join(format!("hmm-scan-bench-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = DiskStore::open(&dir).expect("open bench store");
    let mut rng = Xoshiro256StarStar::seed_from_u64(13);
    let ys = sample(&hmm, t_ckpt, &mut rng).observations;
    let engine = Engine::builder(hmm.clone()).scan_options(opts).build();
    let mut session = engine.open_session(SessionOptions::default());
    session.push(&ys).unwrap();
    let meta = bench_meta();
    let packed_snap = session.snapshot();
    let decimal_snap = to_decimal_json(&packed_snap);

    store.create(1, &meta).expect("create");
    store.compact(1, &meta, &packed_snap).expect("compact packed");
    let packed_bytes = std::fs::metadata(store.path_for(1)).unwrap().len();
    store.compact(1, &meta, &decimal_snap).expect("compact decimal");
    let decimal_bytes = std::fs::metadata(store.path_for(1)).unwrap().len();
    // Restores from either encoding are bit-identical (the compat
    // contract the size win rides on).
    let a = engine.resume_session(&packed_snap).unwrap().finish().unwrap();
    let b = engine.resume_session(&decimal_snap).unwrap().finish().unwrap();
    assert_eq!(a, b, "decimal snapshot restore diverged from packed");
    let ratio = decimal_bytes as f64 / packed_bytes.max(1) as f64;
    println!("\nsnapshot compression (T={t_ckpt} checkpoint log):");
    println!("  decimal (v2) {decimal_bytes:>9} bytes");
    println!("  packed  (v3) {packed_bytes:>9} bytes   ({ratio:.2}× smaller)");
    assert!(
        ratio >= 1.8,
        "packed checkpoint log shrank only {ratio:.2}× (want ≥ 1.8×)"
    );
    let _ = std::fs::remove_dir_all(&dir);

    // ---- timeline overhead: event recording on the append hot path ----
    let tl_off = timeline_append_p50(false, smoke);
    let tl_on = timeline_append_p50(true, smoke);
    let overhead =
        tl_on.as_secs_f64() / tl_off.as_secs_f64().max(1e-9) - 1.0;
    println!("\ntimeline overhead (append hot path, recording on vs off):");
    println!("  timeline=off  append p50 {:>9}", fmt_duration(tl_off));
    println!(
        "  timeline=on   append p50 {:>9}   ({:+.1}%)",
        fmt_duration(tl_on),
        overhead * 100.0
    );
    assert!(
        overhead < 0.05 || smoke,
        "timeline recording added {:.1}% to append p50 (want < 5%)",
        overhead * 100.0
    );

    // ---- tracing overhead: request spans on the wire decode path ------
    let tr_off = traced_decode_p50(false, smoke);
    let tr_on = traced_decode_p50(true, smoke);
    let tr_overhead =
        tr_on.as_secs_f64() / tr_off.as_secs_f64().max(1e-9) - 1.0;
    println!("\ntracing overhead (loopback wire decode, spans on vs off):");
    println!("  tracing=off   decode p50 {:>9}", fmt_duration(tr_off));
    println!(
        "  tracing=on    decode p50 {:>9}   ({:+.1}%)",
        fmt_duration(tr_on),
        tr_overhead * 100.0
    );
    let mut tr_rows: Vec<Json> = Vec::new();
    for (on, p50) in [(false, tr_off), (true, tr_on)] {
        let mut row = BTreeMap::new();
        row.insert("tracing".to_string(), Json::Num(on as u8 as f64));
        row.insert("p50_us".to_string(), Json::Num(p50.as_micros() as f64));
        tr_rows.push(Json::Obj(row));
    }
    hmm_scan::benchx::merge_bench_json(
        std::path::Path::new("BENCH_net.json"),
        "tracing",
        tr_rows,
    )
    .expect("write BENCH_net.json");
    assert!(
        tr_overhead < 0.05 || smoke,
        "span emission added {:.1}% to decode p50 (want < 5%)",
        tr_overhead * 100.0
    );
}

//! Bench target: design-choice ablations called out in DESIGN.md —
//! §V-B block length and native scan thread count.
mod common;

fn main() {
    let (config, quick) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    for s in hmm_scan::experiments::ablation_block_len(&config, quick).unwrap() {
        println!("{}", s.name);
        for &(b, secs) in &s.points {
            println!("  block={b:<8} {secs:.6}s");
        }
    }
    for s in hmm_scan::experiments::ablation_threads(&config, quick).unwrap() {
        println!("{}", s.name);
        for &(th, secs) in &s.points {
            println!("  threads={th:<6} {secs:.6}s");
        }
    }
}

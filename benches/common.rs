//! Shared setup for the figure benches. `HMM_SCAN_BENCH_FULL=1` runs the
//! paper's full T grid (10²…10⁵); the default is a reduced grid so
//! `cargo bench` completes in minutes.
use hmm_scan::config::RunConfig;

#[allow(dead_code)]
pub fn bench_config() -> (RunConfig, bool) {
    let full = std::env::var("HMM_SCAN_BENCH_FULL").as_deref() == Ok("1");
    let config = RunConfig::default();
    (config, !full)
}

//! Shared setup for the figure benches. `HMM_SCAN_BENCH_FULL=1` runs the
//! paper's full T grid (10²…10⁵); the default is a reduced grid so
//! `cargo bench` completes in minutes. All method dispatch goes through
//! the unified `engine::Engine` (see `experiments::run_method`).
use hmm_scan::config::RunConfig;
use hmm_scan::engine::Engine;
use hmm_scan::hmm::{gilbert_elliott, sample};
use hmm_scan::rng::Xoshiro256StarStar;

#[allow(dead_code)]
pub fn bench_config() -> (RunConfig, bool) {
    let full = std::env::var("HMM_SCAN_BENCH_FULL").as_deref() == Ok("1");
    let config = RunConfig::default();
    (config, !full)
}

/// Gilbert–Elliott workload + a ready engine for the hot-path benches.
#[allow(dead_code)]
pub fn ge_engine(t: usize) -> (Engine, Vec<u32>) {
    let config = RunConfig::default();
    let hmm = gilbert_elliott(config.ge);
    let mut rng = Xoshiro256StarStar::seed_from_u64(1);
    let tr = sample(&hmm, t, &mut rng);
    let engine = Engine::builder(hmm).scan_options(config.scan_options()).build();
    (engine, tr.observations)
}

//! Bench target: the L3 hot-path primitives (element init, ⊗/∨ combines,
//! scan sweeps) plus the `engine` serving hot path — workspace reuse vs
//! a fresh engine per call (the per-call D×D allocation cost). These
//! numbers calibrate the GPU simulator's cost model and are the
//! before/after record for EXPERIMENTS.md §Perf.
mod common;

use hmm_scan::benchx::{bench, format_table, BenchConfig};
use hmm_scan::elements::{
    mp_element_chain, sp_element_chain, MpOp, SpOp,
};
use hmm_scan::engine::{Algorithm, Engine};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::scan::{blelloch_scan, AssocOp, ScanOptions};

fn main() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(1);
    let tr = sample(&hmm, 16384, &mut rng);
    let cfg = BenchConfig::default();
    let mut rows = Vec::new();

    rows.push(bench("sp_element_chain/T=16384", cfg, || {
        sp_element_chain(&hmm, &tr.observations)
    }));
    rows.push(bench("mp_element_chain/T=16384", cfg, || {
        mp_element_chain(&hmm, &tr.observations)
    }));

    let sp_elems = sp_element_chain(&hmm, &tr.observations);
    let mp_elems = mp_element_chain(&hmm, &tr.observations);
    let spop = SpOp { d: 4 };
    let mpop = MpOp { d: 4 };
    rows.push(bench("sp_combine/D=4", cfg, || {
        spop.combine(&sp_elems[1], &sp_elems[2])
    }));
    rows.push(bench("mp_combine/D=4", cfg, || {
        mpop.combine(&mp_elems[1], &mp_elems[2])
    }));

    for threads in [1usize, hmm_scan::exec::default_parallelism()] {
        let opts = ScanOptions { threads, ..ScanOptions::default() };
        rows.push(bench(
            &format!("blelloch_sp/T=16384/threads={threads}"),
            BenchConfig::heavy(),
            || {
                let mut v = sp_elems.clone();
                blelloch_scan(&spop, &mut v, opts);
                v.len()
            },
        ));
    }

    // Engine hot path: the serving-loop shape. "reused" amortizes the
    // workspace across calls (zero per-call element allocations once
    // warm); "fresh" pays the allocating path every call — the delta is
    // the workspace win.
    let (mut engine, ys) = common::ge_engine(16384);
    rows.push(bench("engine_smooth_reused/T=16384", BenchConfig::heavy(), || {
        engine.run(Algorithm::SpPar, &ys).unwrap()
    }));
    let opts = engine.scan_options();
    rows.push(bench("engine_smooth_fresh/T=16384", BenchConfig::heavy(), || {
        let mut fresh = Engine::builder(hmm.clone()).scan_options(opts).build();
        fresh.run(Algorithm::SpPar, &ys).unwrap()
    }));
    rows.push(bench("engine_map_reused/T=16384", BenchConfig::heavy(), || {
        engine.run(Algorithm::MpPar, &ys).unwrap()
    }));

    println!("{}", format_table(&rows));
}

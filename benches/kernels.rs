//! Bench target: the specialized kernel tier — const-generic combine
//! microkernels vs the generic semiring matmul, and the batched SoA
//! combine vs the same lanes pushed through the scalar kernel one at a
//! time.
//!
//! The acceptance claim: at the small state dimensions HMM serving
//! lives at (D ≤ 8), the monomorphized D-specialized kernels beat the
//! generic loop by ≥ 2× on combine throughput (asserted below outside
//! smoke mode — the kernels are bit-identical, so the only difference
//! the dispatch makes is speed). Rows are merged into
//! `BENCH_kernels.json` under the `"kernels"` section for trend
//! tooling.
//!
//! `HMM_SCAN_BENCH_SMOKE=1` shrinks the grid and time budget to a CI
//! smoke run (a few seconds total) and skips the throughput assertion.

use std::collections::BTreeMap;
use std::time::Duration;

use hmm_scan::benchx::{bench, black_box, format_table, BenchConfig, Measurement};
use hmm_scan::jsonx::Json;
use hmm_scan::linalg::kernels::{batch_matmul_soa, set_kernels_enabled, SoaBatch};
use hmm_scan::linalg::{matmul_into, matmul_into_generic, Mat};
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::semiring::{MaxPlus, Prob, Semiring};

/// Matmuls per timed closure call: a single D×D combine is nanoseconds,
/// so each sample amortizes the measurement overhead over a fixed batch
/// (identical on both sides of every comparison).
const REPS: usize = 512;

fn random_mat(r: &mut Xoshiro256StarStar, d: usize, log_domain: bool) -> Mat {
    let data = (0..d * d)
        .map(|_| {
            if log_domain {
                r.uniform(-30.0, 5.0)
            } else {
                r.uniform(0.05, 1.5)
            }
        })
        .collect();
    Mat::from_vec(d, d, data)
}

fn row(
    semiring: &str,
    d: usize,
    variant: &str,
    lanes: Option<usize>,
    median: Duration,
    speedup: Option<(&str, f64)>,
) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("semiring".into(), Json::Str(semiring.into()));
    obj.insert("d".into(), Json::Num(d as f64));
    obj.insert("variant".into(), Json::Str(variant.into()));
    if let Some(l) = lanes {
        obj.insert("lanes".into(), Json::Num(l as f64));
    }
    obj.insert("median_us".into(), Json::Num(median.as_secs_f64() * 1e6));
    if let Some((key, v)) = speedup {
        obj.insert(key.into(), Json::Num(v));
    }
    Json::Obj(obj)
}

/// One semiring × one shape: specialized vs generic scalar kernel, then
/// the batched SoA sweep vs the same lanes through the scalar kernel.
fn bench_shape<S: Semiring>(
    d: usize,
    lanes: usize,
    log_domain: bool,
    cfg: BenchConfig,
    smoke: bool,
    table: &mut Vec<Measurement>,
    rows: &mut Vec<Json>,
) {
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xC0DE ^ ((d as u64) << 16));
    let a = random_mat(&mut rng, d, log_domain);
    let b = random_mat(&mut rng, d, log_domain);
    let mut out = Mat::zeros(d, d);

    let spec = bench(&format!("{}/D={d}/specialized", S::NAME), cfg, || {
        for _ in 0..REPS {
            matmul_into::<S>(black_box(&a), black_box(&b), &mut out);
        }
        out.data()[0]
    });
    let generic = bench(&format!("{}/D={d}/generic", S::NAME), cfg, || {
        for _ in 0..REPS {
            matmul_into_generic::<S>(black_box(&a), black_box(&b), &mut out);
        }
        out.data()[0]
    });
    let ratio =
        generic.median.as_secs_f64() / spec.median.as_secs_f64().max(1e-12);
    println!("{}/D={d}: specialized is {ratio:.2}x the generic kernel", S::NAME);
    if !smoke && d <= 8 {
        assert!(
            ratio >= 2.0,
            "{}/D={d}: specialized kernel must be >= 2x generic, got {ratio:.2}x",
            S::NAME
        );
    }

    let la: Vec<Mat> = (0..lanes).map(|_| random_mat(&mut rng, d, log_domain)).collect();
    let lb: Vec<Mat> = (0..lanes).map(|_| random_mat(&mut rng, d, log_domain)).collect();
    let mut sa = SoaBatch::zeros(d, lanes);
    let mut sb = SoaBatch::zeros(d, lanes);
    for (lane, (x, y)) in la.iter().zip(&lb).enumerate() {
        sa.set_lane(lane, x);
        sb.set_lane(lane, y);
    }
    let mut so = SoaBatch::zeros(d, lanes);
    let soa = bench(&format!("{}/D={d}/soa_batched/L={lanes}", S::NAME), cfg, || {
        batch_matmul_soa::<S>(black_box(&sa), black_box(&sb), &mut so);
        so.data()[0]
    });
    let per_lane =
        bench(&format!("{}/D={d}/soa_per_lane/L={lanes}", S::NAME), cfg, || {
            for (x, y) in la.iter().zip(&lb) {
                matmul_into::<S>(black_box(x), black_box(y), &mut out);
            }
            out.data()[0]
        });
    let soa_ratio =
        per_lane.median.as_secs_f64() / soa.median.as_secs_f64().max(1e-12);

    rows.push(row(
        S::NAME,
        d,
        "specialized",
        None,
        spec.median,
        Some(("speedup_vs_generic", ratio)),
    ));
    rows.push(row(S::NAME, d, "generic", None, generic.median, None));
    rows.push(row(
        S::NAME,
        d,
        "soa_batched",
        Some(lanes),
        soa.median,
        Some(("speedup_vs_per_lane", soa_ratio)),
    ));
    rows.push(row(S::NAME, d, "soa_per_lane", Some(lanes), per_lane.median, None));
    table.push(spec);
    table.push(generic);
    table.push(soa);
    table.push(per_lane);
}

fn main() {
    let smoke = std::env::var("HMM_SCAN_BENCH_SMOKE").as_deref() == Ok("1");
    let lanes = if smoke { 32 } else { 256 };
    let cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            time_budget: Duration::from_millis(100),
        }
    } else {
        BenchConfig::default()
    };

    set_kernels_enabled(true);
    let mut table = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    for d in [2usize, 4, 8, 16] {
        bench_shape::<Prob>(d, lanes, false, cfg, smoke, &mut table, &mut rows);
        bench_shape::<MaxPlus>(d, lanes, true, cfg, smoke, &mut table, &mut rows);
    }

    println!("{}", format_table(&table));
    let report = std::path::Path::new("BENCH_kernels.json");
    let n_rows = rows.len();
    hmm_scan::benchx::merge_bench_json(report, "kernels", rows)
        .expect("write BENCH_kernels.json");
    println!(
        "wrote {n_rows} rows to {} (speedup_vs_generic is the microkernel \
         win at a monomorphized shape; speedup_vs_per_lane is the batched \
         SoA sweep's win over lane-at-a-time combines)",
        report.display()
    );
}

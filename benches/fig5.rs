//! Bench target: regenerate paper Fig. 5 (parallel methods on the
//! simulated GPU, linear scale — shows the core-saturation knee).
mod common;

fn main() {
    let (config, _) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    let series = hmm_scan::experiments::fig5(&config).unwrap();
    for s in &series {
        println!("{}", s.name);
        for &(t, secs) in &s.points {
            println!("  T={t:<9} {secs:.6}s (simulated)");
        }
    }
}

//! Bench target: §VI numerical-equivalence report (the paper's ≤1e-16
//! MAE claim, at f64 here).
mod common;

fn main() {
    let (config, quick) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    let report = hmm_scan::experiments::equivalence_report(&config, quick).unwrap();
    println!("{report}");
}

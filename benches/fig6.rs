//! Bench target: regenerate paper Fig. 6 (seq/par speed-up ratios on the
//! simulated GPU).
mod common;

fn main() {
    let (config, _) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    let series = hmm_scan::experiments::fig6(&config).unwrap();
    for s in &series {
        println!("{}", s.name);
        for &(t, ratio) in &s.points {
            println!("  T={t:<9} {ratio:.0}x");
        }
    }
}

//! Bench target: the distributed serving tier — decode throughput
//! scaling across worker counts through the cluster router.
//!
//! Each cell spins up `workers` single-threaded native workers
//! (`exec_threads: 1`, so one worker's decode throughput is its serial
//! decode rate and scaling must come from fan-out), fronts them with a
//! `ClusterRouter` + `NetServer`, and drives the router exactly the way
//! `bench-net` drives one server: `conns` client threads × `pipeline`
//! decodes in flight. The full wire path is measured twice per request
//! (client → router, router → worker).
//!
//! Acceptance (ISSUE 6): on an unloaded multi-core host the 2-worker
//! row must reach ≥ 1.7× the 1-worker throughput and the 4-worker row
//! ≥ 3×. The assertion is skipped under `HMM_SCAN_BENCH_SMOKE=1` and on
//! hosts without enough cores to run 4 workers + router + clients
//! without timeslicing noise.
//!
//! Every cell lands as a row in the `"cluster"` section of
//! `BENCH_net.json` (shared with `bench-net` through
//! `benchx::merge_bench_json`).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hmm_scan::cluster::{ClusterConfig, ClusterRouter};
use hmm_scan::coordinator::{Algo, Coordinator, CoordinatorConfig, DecodeRequest};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::jsonx::Json;
use hmm_scan::net::{NetClient, NetServer, NetServerConfig};
use hmm_scan::rng::Xoshiro256StarStar;

fn pct_us(sorted: &[Duration], p: f64) -> u128 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
    sorted[idx].as_micros()
}

/// One cell: a whole cluster of `workers` single-threaded workers,
/// driven at `conns × pipeline` offered load. Returns (served, wall,
/// sorted latencies).
fn run_cell(
    workers: usize,
    conns: usize,
    pipeline: usize,
    requests: usize,
    t: usize,
) -> (usize, Duration, Vec<Duration>) {
    let hmm = gilbert_elliott(GeParams::default());
    let mut pool = Vec::new();
    for _ in 0..workers {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig::native_only())
                .expect("bench worker coordinator"),
        );
        coord.register_model("ge", hmm.clone());
        let server = NetServer::start(
            Arc::clone(&coord),
            "127.0.0.1:0",
            NetServerConfig {
                // The scaling premise: one decode at a time per worker.
                exec_threads: 1,
                max_connections: conns + 8,
                max_inflight_per_conn: pipeline.max(1) * conns,
                ..NetServerConfig::default()
            },
        )
        .expect("bench worker server");
        let addr = server.local_addr().to_string();
        pool.push((coord, server, addr));
    }
    let addrs: Vec<String> = pool.iter().map(|w| w.2.clone()).collect();
    let mut cluster_config = ClusterConfig::new(addrs);
    cluster_config.decode_pool = (conns * pipeline / workers.max(1)).max(4);
    let router =
        Arc::new(ClusterRouter::new(cluster_config).expect("bench router"));
    let front = NetServer::start(
        Arc::clone(&router),
        "127.0.0.1:0",
        NetServerConfig {
            max_connections: conns + 8,
            max_inflight_per_conn: pipeline.max(1),
            exec_threads: (conns * pipeline).clamp(4, 32),
            ..NetServerConfig::default()
        },
    )
    .expect("bench router front");
    let addr = front.local_addr().to_string();

    let t0 = Instant::now();
    let mut all: Vec<Duration> = Vec::new();
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..conns {
            let hmm = hmm.clone();
            let addr = addr.clone();
            joins.push(scope.spawn(move || {
                let mut client =
                    NetClient::connect(&addr).expect("bench client connect");
                let mut rng =
                    Xoshiro256StarStar::seed_from_u64(0xC105 + c as u64);
                let reqs: Vec<DecodeRequest> = (0..requests)
                    .map(|i| {
                        let ys = sample(&hmm, t, &mut rng).observations;
                        let algo =
                            if i % 2 == 0 { Algo::Smooth } else { Algo::Map };
                        DecodeRequest::new(i as u64, "ge", ys, algo)
                    })
                    .collect();
                client
                    .pipeline_decodes(reqs, pipeline)
                    .expect("pipelined decode through the router failed")
            }));
        }
        for join in joins {
            all.extend(join.join().expect("bench thread panicked"));
        }
    });
    let wall = t0.elapsed();

    let snap = router.metrics().snapshot();
    assert_eq!(
        snap.decode_failovers, 0,
        "loopback bench must not fail over (all workers healthy)"
    );
    front.shutdown(Duration::from_secs(10));
    drop(router);
    for (coord, server, _) in pool {
        server.shutdown(Duration::from_secs(10));
        assert_eq!(
            coord.metrics().snapshot().failed,
            0,
            "no request may fail under the sweep"
        );
    }
    all.sort_unstable();
    (conns * requests, wall, all)
}

fn main() {
    let smoke = std::env::var("HMM_SCAN_BENCH_SMOKE").as_deref() == Ok("1");
    let (worker_grid, conns, pipeline, requests, t): (&[usize], usize, usize, usize, usize) =
        if smoke {
            (&[1, 2], 4, 4, 16, 256)
        } else {
            (&[1, 2, 4], 8, 8, 64, 1024)
        };
    println!(
        "cluster bench (T={t}, {conns} conns × pipeline {pipeline}, \
         {requests} reqs/conn, workers at exec_threads=1)"
    );
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>10}",
        "workers", "req/s", "p50", "p99", "max"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut by_workers: BTreeMap<usize, f64> = BTreeMap::new();
    for &workers in worker_grid {
        let (served, wall, lat) = run_cell(workers, conns, pipeline, requests, t);
        let req_per_s = served as f64 / wall.as_secs_f64();
        let (p50, p99) = (pct_us(&lat, 0.50), pct_us(&lat, 0.99));
        let max = lat.last().map_or(0, |d| d.as_micros());
        println!(
            "{:<10} {:>10.1} {:>9}µ {:>9}µ {:>9}µ",
            workers, req_per_s, p50, p99, max
        );
        by_workers.insert(workers, req_per_s);
        let mut row = BTreeMap::new();
        row.insert("workers".to_string(), Json::Num(workers as f64));
        row.insert("conns".to_string(), Json::Num(conns as f64));
        row.insert("pipeline".to_string(), Json::Num(pipeline as f64));
        row.insert("t".to_string(), Json::Num(t as f64));
        row.insert("requests".to_string(), Json::Num(served as f64));
        row.insert("req_per_s".to_string(), Json::Num(req_per_s));
        row.insert("p50_us".to_string(), Json::Num(p50 as f64));
        row.insert("p99_us".to_string(), Json::Num(p99 as f64));
        row.insert("max_us".to_string(), Json::Num(max as f64));
        rows.push(Json::Obj(row));
    }
    let report = std::path::Path::new("BENCH_net.json");
    hmm_scan::benchx::merge_bench_json(report, "cluster", rows)
        .expect("write BENCH_net.json");
    println!("\nwrote {} rows to {}", by_workers.len(), report.display());

    // Scaling acceptance — only meaningful when the host can actually
    // run 4 workers + router + clients in parallel and the sweep is not
    // the CI smoke grid.
    let cores = hmm_scan::exec::default_parallelism();
    if !smoke && cores >= 8 {
        let base = by_workers[&1];
        if let Some(&two) = by_workers.get(&2) {
            let speedup = two / base;
            println!("scaling 1→2 workers: {speedup:.2}×");
            assert!(
                speedup >= 1.7,
                "2-worker throughput must reach ≥1.7× of 1 worker \
                 (got {speedup:.2}×)"
            );
        }
        if let Some(&four) = by_workers.get(&4) {
            let speedup = four / base;
            println!("scaling 1→4 workers: {speedup:.2}×");
            assert!(
                speedup >= 3.0,
                "4-worker throughput must reach ≥3× of 1 worker \
                 (got {speedup:.2}×)"
            );
        }
    } else {
        println!(
            "scaling assertion skipped (smoke={smoke}, cores={cores} < 8)"
        );
    }
}

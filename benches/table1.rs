//! Bench target: the Table I analogue — this system's measured CPU and
//! simulated GPU speedups per method family.
mod common;

fn main() {
    let (config, quick) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    let table = hmm_scan::experiments::table1(&config, quick).unwrap();
    println!("{table}");
}

//! Bench target: regenerate paper Fig. 3 (CPU runtimes of all seven
//! methods vs sequence length, measured on this machine).
mod common;

fn main() {
    let (config, quick) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    let series = hmm_scan::experiments::fig3(&config, quick).unwrap();
    for s in &series {
        println!("{}", s.name);
        for &(t, secs) in &s.points {
            println!("  T={t:<9} {secs:.6}s");
        }
    }
    println!("(csv + ascii plot in {})", config.out_dir.display());
}

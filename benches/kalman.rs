//! Bench target: the Kalman tier — classical O(T) filtering/smoothing
//! vs the parallel-scan variants, swept over sequence length and state
//! dimension.
//!
//! The acceptance claim mirrors the discrete figures: `kf_par`/`ks_par`
//! overtake their sequential references as T grows (span O(log T) on
//! enough threads), and the crossover moves earlier as the per-step
//! combine gets fatter (state dim up). Rows are merged into
//! `BENCH_kalman.json` under the `"kalman"` section for trend tooling.
//!
//! `HMM_SCAN_BENCH_SMOKE=1` shrinks the grid and time budget to a CI
//! smoke run (a few seconds total).

use std::collections::BTreeMap;
use std::time::Duration;

use hmm_scan::benchx::{bench, black_box, format_table, BenchConfig};
use hmm_scan::engine::Algorithm;
use hmm_scan::jsonx::Json;
use hmm_scan::kalman::{KalmanEngine, Lgssm};
use hmm_scan::linalg::Mat;
use hmm_scan::rng::Xoshiro256StarStar;

/// A well-conditioned n-state model observing its first ⌈n/2⌉ states: a
/// lightly-rotated contraction for A (stable, non-diagonal so the
/// combines exercise full matrix paths), isotropic Q/R, unit prior.
fn synthetic_model(n: usize) -> Lgssm {
    let m = n.div_ceil(2);
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        a[(i, i)] = 0.95;
        a[(i, (i + 1) % n)] += 0.05;
    }
    let mut q = Mat::zeros(n, n);
    let mut p0 = Mat::zeros(n, n);
    for i in 0..n {
        q[(i, i)] = 0.1;
        p0[(i, i)] = 1.0;
    }
    let mut h = Mat::zeros(m, n);
    for i in 0..m {
        h[(i, i)] = 1.0;
    }
    let mut r = Mat::zeros(m, m);
    for i in 0..m {
        r[(i, i)] = 0.5;
    }
    Lgssm::new(a, q, h, r, vec![0.0; n], p0).expect("synthetic model")
}

fn main() {
    let smoke = std::env::var("HMM_SCAN_BENCH_SMOKE").as_deref() == Ok("1");
    let t_grid: &[usize] = if smoke {
        &[4096]
    } else {
        &[1024, 4096, 16384, 65536]
    };
    let n_grid: &[usize] = if smoke { &[4] } else { &[2, 4, 8] };
    let cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            time_budget: Duration::from_millis(100),
        }
    } else {
        BenchConfig::default()
    };

    let algs = [
        Algorithm::KfSeq,
        Algorithm::KfPar,
        Algorithm::KsSeq,
        Algorithm::KsPar,
    ];
    let mut table = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    for &n in n_grid {
        let model = synthetic_model(n);
        let obs_dim = model.obs_dim();
        for &t in t_grid {
            // Inference cost is data-independent; uniform noise keeps
            // every value finite without simulating the model.
            let mut rng = Xoshiro256StarStar::seed_from_u64((n * t) as u64);
            let obs: Vec<f64> =
                (0..t * obs_dim).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let mut engine = KalmanEngine::new(model.clone());

            // Parallel and sequential answers agree before we time them
            // (same check the equivalence tests make, looser here since
            // the synthetic models vary).
            let ls = engine
                .run(Algorithm::KfSeq, &obs)
                .unwrap()
                .log_likelihood();
            let lp = engine
                .run(Algorithm::KfPar, &obs)
                .unwrap()
                .log_likelihood();
            let rel = ((ls - lp) / ls.abs().max(1.0)).abs();
            assert!(rel < 1e-6, "n={n} T={t}: seq/par rel err {rel:e}");

            let mut medians = BTreeMap::new();
            for alg in algs {
                let meas = bench(&format!("{}/n={n}/T={t}", alg.name()), cfg, || {
                    engine
                        .run(alg, black_box(&obs))
                        .unwrap()
                        .log_likelihood()
                });
                medians.insert(alg.name(), meas.median);
                table.push(meas);
            }
            for alg in algs {
                let median = medians[alg.name()];
                let baseline = medians[alg.seq_variant().name()];
                let mut row = BTreeMap::new();
                row.insert("algorithm".into(), Json::Str(alg.name().into()));
                row.insert("t".into(), Json::Num(t as f64));
                row.insert("state_dim".into(), Json::Num(n as f64));
                row.insert(
                    "median_us".into(),
                    Json::Num(median.as_secs_f64() * 1e6),
                );
                row.insert(
                    "speedup_vs_seq".into(),
                    Json::Num(
                        baseline.as_secs_f64()
                            / median.as_secs_f64().max(1e-12),
                    ),
                );
                rows.push(Json::Obj(row));
            }
        }
    }

    println!("{}", format_table(&table));
    let report = std::path::Path::new("BENCH_kalman.json");
    let n_rows = rows.len();
    hmm_scan::benchx::merge_bench_json(report, "kalman", rows)
        .expect("write BENCH_kalman.json");
    println!(
        "wrote {n_rows} rows to {} (speedup_vs_seq > 1 marks the \
         parallel-scan win; expect it past the thread-count crossover)",
        report.display()
    );
}

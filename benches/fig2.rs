//! Bench target: regenerate paper Fig. 2 (GE example trajectory).
mod common;

fn main() {
    let (config, _) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    let plot = hmm_scan::experiments::fig2(&config).unwrap();
    println!("{plot}");
}

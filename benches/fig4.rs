//! Bench target: regenerate paper Fig. 4 (GPU runtimes — work-span
//! simulated; see DESIGN.md §4 substitution note).
mod common;

fn main() {
    let (config, _) = common::bench_config();
    std::fs::create_dir_all(&config.out_dir).unwrap();
    let series = hmm_scan::experiments::fig4(&config).unwrap();
    for s in &series {
        println!("{}", s.name);
        for &(t, secs) in &s.points {
            println!("  T={t:<9} {secs:.6}s (simulated)");
        }
    }
}

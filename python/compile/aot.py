"""AOT compiler: lower every L2 entry to HLO text + a JSON manifest.

Run once at build time (``make artifacts``); the Rust runtime loads the
results via ``xla::HloModuleProto::from_text_file`` and never touches
Python again.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
pinned xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifacts are emitted for a grid of static shapes:

* core entries (sp/mp/bs × par/seq + viterbi) at each (T, D, M),
* block-wise entries (paper §V-B) at each (block_len, D, M) — these are
  what the coordinator's temporal sharder uses to serve T beyond the
  largest compiled core artifact.

``manifest.json`` describes every artifact (entry, shapes, dtypes, i/o
signature) and is the single source of truth for the Rust artifact
registry (rust/src/runtime/manifest.rs).

Usage:  python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def core_signature(t, d, m, entry):
    """Input/output signature of a core (whole-sequence) entry."""
    inputs = [
        {"name": "pi", "shape": [d, d], "dtype": F32},
        {"name": "obs", "shape": [d, m], "dtype": F32},
        {"name": "prior", "shape": [d], "dtype": F32},
        {"name": "ys", "shape": [t], "dtype": I32},
        {"name": "valid", "shape": [t], "dtype": F32},
    ]
    if entry in ("sp_par", "sp_seq", "bs_par", "bs_seq"):
        outputs = [
            {"name": "gamma", "shape": [t, d], "dtype": F32},
            {"name": "loglik", "shape": [], "dtype": F32},
        ]
    else:  # mp_par, mp_seq, viterbi
        outputs = [
            {"name": "path", "shape": [t], "dtype": I32},
            {"name": "logp", "shape": [], "dtype": F32},
        ]
    return inputs, outputs


def block_signature(l, d, m, entry):
    """Input/output signature of a block-wise (§V-B) entry."""
    inputs = [
        {"name": "pi", "shape": [d, d], "dtype": F32},
        {"name": "obs", "shape": [d, m], "dtype": F32},
        {"name": "prior", "shape": [d], "dtype": F32},
        {"name": "ys", "shape": [l], "dtype": I32},
        {"name": "valid", "shape": [l], "dtype": F32},
    ]
    if "finalize" in entry:
        inputs += [
            {"name": "fin", "shape": [d, d], "dtype": F32},
            {"name": "bin", "shape": [d, d], "dtype": F32},
        ]
        if entry.startswith("sp_"):
            outputs = [{"name": "gamma", "shape": [l, d], "dtype": F32}]
        else:
            outputs = [{"name": "path", "shape": [l], "dtype": I32}]
    else:  # fold
        if entry.startswith("sp_"):
            outputs = [
                {"name": "mat", "shape": [d, d], "dtype": F32},
                {"name": "log", "shape": [], "dtype": F32},
            ]
        else:
            outputs = [{"name": "mat", "shape": [d, d], "dtype": F32}]
    return inputs, outputs


def spec_of(io):
    dt = {F32: jnp.float32, I32: jnp.int32}[io["dtype"]]
    return jax.ShapeDtypeStruct(tuple(io["shape"]), dt)


def lower_entry(fn, inputs):
    # keep_unused: some entries ignore an input (e.g. `prior` in the
    # *_mid block entries); the rust runtime feeds every manifest input,
    # so the parameter must survive lowering.
    return jax.jit(fn, keep_unused=True).lower(*[spec_of(i) for i in inputs])


def emit(out_dir, name, entry, fn, inputs, outputs, meta):
    t0 = time.time()
    text = to_hlo_text(lower_entry(fn, inputs))
    path = out_dir / f"{name}.hlo.txt"
    path.write_text(text)
    rec = {
        "name": name,
        "entry": entry,
        "path": path.name,
        "inputs": inputs,
        "outputs": outputs,
        **meta,
    }
    print(f"  {name}: {len(text)/1e3:.0f} kB in {time.time()-t0:.1f}s")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--t-grid", default="128,1024,8192",
        help="comma-separated sequence lengths for core artifacts",
    )
    ap.add_argument(
        "--dims", default="4x2,8x4",
        help="comma-separated DxM pairs (states x observation symbols)",
    )
    ap.add_argument(
        "--block-len", type=int, default=1024,
        help="block length for the §V-B sharding artifacts",
    )
    ap.add_argument(
        "--quick", action="store_true",
        help="small grid for CI: T=64, D=4, M=2, block 32",
    )
    args = ap.parse_args()

    if args.quick:
        t_grid, dims, block_len = [64], [(4, 2)], 32
    else:
        t_grid = [int(t) for t in args.t_grid.split(",")]
        dims = [tuple(int(v) for v in p.split("x")) for p in args.dims.split(",")]
        block_len = args.block_len

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    records = []

    for d, m in dims:
        for t in t_grid:
            for entry, fn in model.CORE_ENTRIES.items():
                name = f"{entry}_T{t}_D{d}_M{m}"
                inputs, outputs = core_signature(t, d, m, entry)
                records.append(
                    emit(out_dir, name, entry, fn, inputs, outputs,
                         {"t": t, "d": d, "m": m, "kind": "core"})
                )
        for entry, fn in {**model.BLOCK_FOLD_ENTRIES,
                          **model.BLOCK_FINALIZE_ENTRIES}.items():
            name = f"{entry}_L{block_len}_D{d}_M{m}"
            inputs, outputs = block_signature(block_len, d, m, entry)
            records.append(
                emit(out_dir, name, entry, fn, inputs, outputs,
                     {"t": block_len, "d": d, "m": m, "kind": "block"})
            )

    manifest = {
        "version": 1,
        "generator": "compile.aot",
        "interchange": "hlo-text",
        "artifacts": records,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(records)} artifacts + manifest.json to {out_dir}")


if __name__ == "__main__":
    main()

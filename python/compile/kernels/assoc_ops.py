"""L1 — Pallas kernels for the paper's associative scan combines.

The hot spot of the parallel sum-product / max-product algorithms is the
binary associative combine applied to batches of (D, D) potential matrices
at every level of the parallel scan (paper Eq. 16 and Eq. 42), plus the
embarrassingly-parallel element initialization (Definition 3 / Eq. 36).

TPU mapping (see DESIGN.md §Hardware-Adaptation):

* ``sp_combine`` is a batched D×D matmul with per-matrix rescaling — on a
  real TPU the contraction maps onto the MXU and the batch dimension is
  tiled HBM→VMEM via the BlockSpec below (PAIR_TILE pairs per grid step;
  VMEM footprint = 3 tiles * D*D * 4B + 3 * PAIR_TILE * 4B).
* ``mp_combine`` is a tropical (max-plus) matmul — no MXU contraction
  exists for (max, +), so it targets the VPU with whole (tile, D, D)
  blocks resident in VMEM.
* ``element_init`` is bandwidth-bound: a broadcasted outer product of the
  transition matrix with per-step emission columns, tiled along T.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers the kernel to plain
HLO so the artifact runs anywhere. Real-TPU performance is *estimated*
from the BlockSpec footprints in EXPERIMENTS.md §Perf.

Set HMM_SCAN_NO_PALLAS=1 to bypass Pallas and use the jnp oracles from
``ref.py`` (used by tests to localize failures).
"""

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Number of (D, D) matrix pairs combined per grid step. 64 pairs of f32
# 8×8 matrices = 3 * 64*64*4 B = 48 KiB of VMEM for in/out tiles — well
# under the ~16 MiB/core budget; chosen so the grid loop dominates over
# per-step overhead while keeping the last partial tile small.
PAIR_TILE = 64

# Element-init tile along the time axis.
INIT_TILE = 256

USE_PALLAS = os.environ.get("HMM_SCAN_NO_PALLAS", "0") != "1"


def _grid_1d(n, tile):
    """(tile, grid) covering n items; pallas pads the last partial block."""
    t = min(n, tile)
    return t, (n + t - 1) // t


# ---------------------------------------------------------------------------
# Sum-product combine ⊗ (Eq. 16) on rescaled elements
# ---------------------------------------------------------------------------


def _sp_combine_kernel(am_ref, al_ref, bm_ref, bl_ref, cm_ref, cl_ref):
    am = am_ref[...]
    bm = bm_ref[...]
    c = jnp.einsum(
        "bij,bjk->bik", am, bm, preferred_element_type=jnp.float32
    )
    m = jnp.maximum(jnp.max(c, axis=(1, 2), keepdims=True), ref.TINY)
    cm_ref[...] = c / m
    cl_ref[...] = al_ref[...] + bl_ref[...] + jnp.log(m[:, 0, 0])


def sp_combine(a, b):
    """Combine two batches of sum-product elements: a ⊗ b.

    a, b: tuples (mats (B,D,D) f32, logs (B,) f32). Returns the same
    structure. B may be 0 (the parallel scan's odd/even split produces
    empty slices at the deepest levels) — returned unchanged.
    """
    am, al = a
    bm, bl = b
    batch = am.shape[0]
    if batch == 0 or not USE_PALLAS:
        return ref.sp_combine_ref(am, al, bm, bl)
    d = am.shape[1]
    tile, grid = _grid_1d(batch, PAIR_TILE)
    mat_spec = pl.BlockSpec((tile, d, d), lambda i: (i, 0, 0))
    log_spec = pl.BlockSpec((tile,), lambda i: (i,))
    cm, cl = pl.pallas_call(
        _sp_combine_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((batch, d, d), am.dtype),
            jax.ShapeDtypeStruct((batch,), al.dtype),
        ),
        grid=(grid,),
        in_specs=[mat_spec, log_spec, mat_spec, log_spec],
        out_specs=(mat_spec, log_spec),
        interpret=True,
    )(am, al, bm, bl)
    return cm, cl


# ---------------------------------------------------------------------------
# Max-product combine ∨ (Eq. 42) in log domain (max-plus matmul)
# ---------------------------------------------------------------------------


def _mp_combine_kernel(a_ref, b_ref, c_ref):
    a = a_ref[...]
    b = b_ref[...]
    # (B, D, D, 1) + (B, 1, D, D) → max over the contracted axis j.
    c_ref[...] = jnp.max(a[:, :, :, None] + b[:, None, :, :], axis=2)


def mp_combine(a, b):
    """Tropical combine of two batches of log-domain elements: a ∨ b."""
    batch = a.shape[0]
    if batch == 0 or not USE_PALLAS:
        return ref.mp_combine_ref(a, b)
    d = a.shape[1]
    tile, grid = _grid_1d(batch, PAIR_TILE)
    spec = pl.BlockSpec((tile, d, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _mp_combine_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, d, d), a.dtype),
        grid=(grid,),
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# Element initialization (Definition 3 / Eq. 36)
# ---------------------------------------------------------------------------


def _sp_element_init_kernel(pi_ref, em_ref, valid_ref, eye_ref, mat_ref, log_ref):
    pi = pi_ref[...]          # (1, D, D) — same transition matrix every step
    em = em_ref[...]          # (tile, D)
    valid = valid_ref[...]    # (tile,)
    eye = eye_ref[...]        # (1, D, D)
    psi = pi * em[:, None, :]
    v = valid[:, None, None]
    psi = v * psi + (1.0 - v) * eye
    m = jnp.maximum(jnp.max(psi, axis=(1, 2), keepdims=True), ref.TINY)
    mat_ref[...] = psi / m
    log_ref[...] = jnp.log(m[:, 0, 0])


def sp_element_init(pi, em, valid):
    """Build interior sum-product elements ψ_{t-1,t} = Π ∘ e_t, rescaled.

    pi (D,D), em (T,D), valid (T,) → (mats (T,D,D), logs (T,)).
    """
    t_len, d = em.shape
    if not USE_PALLAS:
        return ref.sp_element_init_ref(pi, em, valid)
    tile, grid = _grid_1d(t_len, INIT_TILE)
    eye = jnp.eye(d, dtype=pi.dtype)[None]
    mats, logs = pl.pallas_call(
        _sp_element_init_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t_len, d, d), pi.dtype),
            jax.ShapeDtypeStruct((t_len,), pi.dtype),
        ),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, d, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1, d, d), lambda i: (0, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tile, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        interpret=True,
    )(pi[None], em, valid, eye)
    return mats, logs


def _mp_element_init_kernel(lpi_ref, lem_ref, valid_ref, leye_ref, out_ref):
    lpi = lpi_ref[...]
    lem = lem_ref[...]
    valid = valid_ref[...]
    leye = leye_ref[...]
    psi = lpi + lem[:, None, :]
    out_ref[...] = jnp.where(valid[:, None, None] > 0.5, psi, leye)


def mp_element_init(log_pi, log_em, valid):
    """Build interior max-product (log-domain) elements, masked → identity."""
    t_len, d = log_em.shape
    if not USE_PALLAS:
        return ref.mp_element_init_ref(log_pi, log_em, valid)
    tile, grid = _grid_1d(t_len, INIT_TILE)
    logeye = jnp.where(jnp.eye(d, dtype=bool), 0.0, ref.NEG_INF).astype(
        log_pi.dtype
    )[None]
    return pl.pallas_call(
        _mp_element_init_kernel,
        out_shape=jax.ShapeDtypeStruct((t_len, d, d), log_pi.dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, d, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1, d, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d, d), lambda i: (i, 0, 0)),
        interpret=True,
    )(log_pi[None], log_em, valid, logeye)

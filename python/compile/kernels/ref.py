"""Pure-jnp reference oracles for the Pallas kernels in assoc_ops.py.

Every kernel in the L1 layer has an exact jnp counterpart here. The pytest
suite asserts allclose between the two over shape/dtype sweeps (hypothesis),
and the L2 model can be switched to the reference path with
HMM_SCAN_NO_PALLAS=1 for debugging.

Element conventions (see DESIGN.md §2):

* Sum-product element: pair ``(mats, logs)`` with ``mats`` of shape
  (B, D, D), nonnegative, max-normalized to 1, and ``logs`` of shape (B,)
  carrying the log scale, so the represented potential matrix is
  ``exp(logs[b]) * mats[b]``.
* Max-product element: (B, D, D) log-domain matrix (max-plus semiring).
"""

import jax.numpy as jnp

# Floor used when renormalizing sum-product elements: guards against an
# all-zero product (fully inconsistent evidence) producing -inf scales.
TINY = 1e-30

# Log-domain "minus infinity" that stays well clear of f32 overflow when a
# few of them are added together.
NEG_INF = -1e30


def sp_combine_ref(am, al, bm, bl):
    """Sum-product combine (paper Eq. 16) on rescaled elements.

    (M1, s1) ⊗ (M2, s2) = (M1 M2 / c, s1 + s2 + log c),  c = max(M1 M2).
    """
    c = jnp.einsum("bij,bjk->bik", am, bm)
    m = jnp.maximum(jnp.max(c, axis=(1, 2), keepdims=True), TINY)
    return c / m, al + bl + jnp.log(m[:, 0, 0])


def mp_combine_ref(a, b):
    """Max-product combine (paper Eq. 42) in log domain (max-plus matmul).

    c[b, i, k] = max_j a[b, i, j] + b[b, j, k]
    """
    return jnp.max(a[:, :, :, None] + b[:, None, :, :], axis=2)


def sp_element_init_ref(pi, em, valid):
    """Sum-product elements a_{t-1:t} from transition matrix and emissions.

    pi:    (D, D) transition matrix  Π[i, j] = p(x_t = j | x_{t-1} = i)
    em:    (T, D) per-step emission column e_t[j] = p(y_t | x_t = j)
    valid: (T,) float mask; masked (0.0) steps produce the identity element
           so artifacts of a fixed T can serve shorter sequences (padding).

    Returns (mats (T,D,D), logs (T,)) max-normalized. NOTE: the t = 0
    element must afterwards be replaced with the prior-broadcast element
    (see ``first_element_ref``); this function builds the uniform interior
    elements ψ_{t-1,t} = Π ∘ e_t only.
    """
    d = pi.shape[0]
    psi = pi[None, :, :] * em[:, None, :]
    eye = jnp.eye(d, dtype=pi.dtype)[None]
    psi = valid[:, None, None] * psi + (1.0 - valid[:, None, None]) * eye
    m = jnp.maximum(jnp.max(psi, axis=(1, 2), keepdims=True), TINY)
    return psi / m, jnp.log(m[:, 0, 0])


def mp_element_init_ref(log_pi, log_em, valid):
    """Max-product (log-domain) elements; masked steps → max-plus identity."""
    d = log_pi.shape[0]
    psi = log_pi[None, :, :] + log_em[:, None, :]
    logeye = jnp.where(jnp.eye(d, dtype=bool), 0.0, NEG_INF).astype(psi.dtype)[None]
    return jnp.where(valid[:, None, None] > 0.5, psi, logeye)


def first_element_ref(prior, e0):
    """The a_{0:1} element: rows broadcast ψ_1(x_1) = prior(x_1) p(y_1|x_1).

    Returns ((D,D) matrix max-normalized, log scale scalar).
    """
    row = prior * e0
    m = jnp.maximum(jnp.max(row), TINY)
    d = prior.shape[0]
    return jnp.broadcast_to(row / m, (d, d)), jnp.log(m)


def mp_first_element_ref(log_prior, log_e0):
    """Log-domain a_{0:1}: rows broadcast log prior + log emission."""
    row = log_prior + log_e0
    d = row.shape[0]
    return jnp.broadcast_to(row, (d, d))

"""L2 — JAX compute graphs for HMM inference (build-time only).

Implements every algorithm the paper benchmarks (§VI), plus the block-wise
entries (§V-B) used by the Rust coordinator's temporal sharder:

  parallel (associative-scan, O(log T) span):
    sp_par   — parallel sum-product smoother      (Algorithm 3)
    mp_par   — parallel max-product MAP           (Algorithm 5)
    bs_par   — parallel Bayesian smoother         (Särkkä & G-F 2021 [30])
  sequential baselines (lax.scan, O(T) span):
    sp_seq   — classical sum-product / two-filter (Algorithm 1 + Eq. 22)
    mp_seq   — sequential max-product             (Lemma 3 + Theorem 4)
    viterbi  — classical Viterbi                  (Algorithm 4)
    bs_seq   — forward filter + RTS smoother
  block-wise (paper §V-B), used by the L3 temporal sharder:
    sp_block_fold_{first,mid}, sp_block_finalize_{first,mid}
    mp_block_fold_{first,mid}, mp_block_finalize_{first,mid}

Common signature: ``(pi (D,D), obs (D,M), prior (D,), ys (T,) i32,
valid (T,) f32)``. ``valid`` masks padding: masked steps contribute
identity elements, so one compiled artifact of length T serves any
sequence of length ≤ T (the router pads). Outputs at masked positions are
unspecified.

The parallel entries call the L1 Pallas kernels (kernels/assoc_ops.py)
inside ``jax.lax.associative_scan``; everything lowers into a single HLO
module per entry via aot.py.
"""

import jax.numpy as jnp
from jax import lax

from .kernels import assoc_ops as ko
from .kernels import ref

NEG_INF = ref.NEG_INF
TINY = ref.TINY


def _emissions(obs, ys):
    """Per-step emission columns e_t[j] = p(y_t | x_t = j); (T, D)."""
    return jnp.take(obs, ys, axis=1).T


def _safe_log(x):
    return jnp.where(x > 0, jnp.log(jnp.maximum(x, TINY)), NEG_INF)


def _masked_trans(pi, valid):
    """(T,D,D) per-step transition: Π on valid steps, I on padding."""
    d = pi.shape[0]
    eye = jnp.eye(d, dtype=pi.dtype)
    v = valid[:, None, None]
    return v * pi[None] + (1.0 - v) * eye[None]


def _masked_emis(em, valid):
    """(T,D) per-step emissions: e_t on valid steps, all-ones on padding."""
    return valid[:, None] * em + (1.0 - valid[:, None])


# jax.lax.associative_scan(reverse=True) combines elements in *descending*
# index order (it reverses inputs and outputs but not the operator — the
# paper's §III-B notes the operation itself must be reversed too). Our
# suffix products a_{k:T+1} = a_k ⊗ a_{k+1} ⊗ … are ascending, so the
# reversed scans use the flipped operator.
def _sp_combine_flip(a, b):
    return ko.sp_combine(b, a)


def _mp_combine_flip(a, b):
    return ko.mp_combine(b, a)


# ===========================================================================
# Parallel sum-product smoother (Algorithm 3)
# ===========================================================================


def sp_par(pi, obs, prior, ys, valid):
    """Parallel two-filter smoother: marginals (T,D) + log-likelihood.

    Elements per Definition 3; ⊗ per Eq. 16 (Pallas kernel); forward scan
    for ψ^f, reversed scan for ψ^b, marginals via Eq. (22).
    """
    em = _emissions(obs, ys)
    mats, logs = ko.sp_element_init(pi, em, valid)
    f0m, f0l = ref.first_element_ref(prior, em[0])
    mats = mats.at[0].set(f0m)
    logs = logs.at[0].set(f0l)

    fwd_m, fwd_l = lax.associative_scan(ko.sp_combine, (mats, logs))

    d = pi.shape[0]
    ones = jnp.ones((1, d, d), dtype=mats.dtype)
    bwd_elems_m = jnp.concatenate([mats[1:], ones], axis=0)
    bwd_elems_l = jnp.concatenate([logs[1:], jnp.zeros((1,), logs.dtype)])
    bwd_m, _ = lax.associative_scan(
        _sp_combine_flip, (bwd_elems_m, bwd_elems_l), reverse=True
    )

    # Eq. (22): p(x_k) ∝ ψ^f(x_k) ψ^b(x_k); rescale logs cancel under the
    # per-step normalization.
    raw = fwd_m[:, 0, :] * bwd_m[:, :, 0]
    gamma = raw / jnp.maximum(jnp.sum(raw, axis=1, keepdims=True), TINY)
    loglik = fwd_l[-1] + jnp.log(jnp.maximum(jnp.sum(fwd_m[-1, 0, :]), TINY))
    return gamma, loglik


# ===========================================================================
# Parallel max-product MAP (Algorithm 5)
# ===========================================================================


def mp_par(pi, obs, prior, ys, valid):
    """Parallel Viterbi via max-product scans: path (T,) i32 + log prob.

    Log-domain elements; ∨ per Eq. (42) (tropical Pallas kernel); the MAP
    state at each k from Eq. (40). Assumes a unique MAP (paper §IV-A).
    """
    em = _emissions(obs, ys)
    lpi = _safe_log(pi)
    lem = _safe_log(em)
    elems = ko.mp_element_init(lpi, lem, valid)
    first = ref.mp_first_element_ref(_safe_log(prior), lem[0])
    elems = elems.at[0].set(first)

    fwd = lax.associative_scan(ko.mp_combine, elems)

    d = pi.shape[0]
    term = jnp.zeros((1, d, d), dtype=elems.dtype)  # ψ_{T,T+1} = 1 → log 0
    bwd_elems = jnp.concatenate([elems[1:], term], axis=0)
    bwd = lax.associative_scan(_mp_combine_flip, bwd_elems, reverse=True)

    delta = fwd[:, 0, :] + bwd[:, :, 0]  # Eq. (40) per step k
    path = jnp.argmax(delta, axis=1).astype(jnp.int32)
    logp = jnp.max(fwd[-1, 0, :])
    return path, logp


# ===========================================================================
# Parallel Bayesian smoother (BS-Par, Ref. [30] discrete analogue)
# ===========================================================================


def _bs_filter_combine(a, b):
    """Combine of filtering elements (f, ĝ, γ): discrete analogue of the
    parallel Bayesian filter element of [30].

    f(x_{k-1}, x_k) = p(x_k | y-segment, x_{k-1}) — row-stochastic (D,D)
    ĝ(x_{k-1})      = rescaled p(y-segment | x_{k-1}), max-normalized
    γ               = log scale of ĝ
    """
    f1, g1, c1 = a
    f2, g2, c2 = b
    s = jnp.einsum("bij,bj->bi", f1, g2)  # Σ_j f1[i,j] ĝ2[j]
    sc = jnp.maximum(s, TINY)
    f12 = jnp.einsum("bij,bj,bjk->bik", f1, g2, f2) / sc[:, :, None]
    g12 = g1 * s
    m = jnp.maximum(jnp.max(g12, axis=1, keepdims=True), TINY)
    return f12, g12 / m, c1 + c2 + jnp.log(m[:, 0])


def bs_par(pi, obs, prior, ys, valid):
    """Parallel Bayesian (filter + RTS) smoother: marginals + loglik.

    Forward: associative scan of filtering elements. Backward: associative
    scan (reversed, flipped matmul) of the RTS conditionals
    S_t[m, i] = p(x_t = i | x_{t+1} = m, y_{1:t}). This is the RTS-type
    smoother of [30], kept distinct from sp_par's two-filter form — the
    paper benchmarks both.
    """
    d = pi.shape[0]
    em = _emissions(obs, ys)
    pt = _masked_trans(pi, valid)  # (T,D,D)
    et = _masked_emis(em, valid)  # (T,D)

    # Filtering elements. Interior: f_t ∝ Π ∘ e_t row-normalized,
    # ĝ_t[i] = Σ_j Π[i,j] e_t[j]. First: rows = posterior of x_0.
    w = pt * et[:, None, :]
    g = jnp.maximum(jnp.sum(w, axis=2), TINY)  # (T,D)
    f = w / g[:, :, None]
    w0 = prior * et[0]
    g0 = jnp.maximum(jnp.sum(w0), TINY)
    f = f.at[0].set(jnp.broadcast_to(w0 / g0, (d, d)))
    g = g.at[0].set(jnp.full((d,), g0))
    gm = jnp.maximum(jnp.max(g, axis=1, keepdims=True), TINY)
    gh = g / gm
    gc = jnp.log(gm[:, 0])

    ff, ghs, gcs = lax.associative_scan(_bs_filter_combine, (f, gh, gc))
    filtered = ff[:, 0, :]  # rows identical after absorbing the first elem

    # Log-likelihood from the full-interval element:
    # p(y_{1:T}) = g_full(x_0), constant in x_0.
    loglik = gcs[-1] + jnp.log(jnp.maximum(ghs[-1, 0], TINY))

    # RTS backward conditionals S_t[m, i] ∝ filtered_t[i] Π_t[i, m].
    s_un = filtered[:-1, None, :] * jnp.transpose(pt[1:], (0, 2, 1))
    s_norm = jnp.maximum(jnp.sum(s_un, axis=2, keepdims=True), TINY)
    s_mats = s_un / s_norm  # (T-1, D, D)
    eye = jnp.eye(d, dtype=pi.dtype)[None]
    elems = jnp.concatenate([s_mats, eye], axis=0)  # terminal identity

    def back_combine(u, v):
        # R_t = R_{t+1} @ S_t: under reverse=True the first operand u is
        # the later-index accumulator, so plain order is the descending
        # product we need.
        r = jnp.einsum("bij,bjk->bik", u, v)
        return r / jnp.maximum(jnp.sum(r, axis=2, keepdims=True), TINY)

    rmats = lax.associative_scan(back_combine, elems, reverse=True)
    gamma = jnp.einsum("m,bmi->bi", filtered[-1], rmats)
    gamma = gamma / jnp.maximum(jnp.sum(gamma, axis=1, keepdims=True), TINY)
    return gamma, loglik


# ===========================================================================
# Sequential baselines
# ===========================================================================


def sp_seq(pi, obs, prior, ys, valid):
    """Classical sum-product (Algorithm 1) with per-step rescaling."""
    em = _emissions(obs, ys)
    pt = _masked_trans(pi, valid)
    et = _masked_emis(em, valid)

    a0 = prior * et[0]
    c0 = jnp.maximum(jnp.sum(a0), TINY)

    def fwd_step(carry, inp):
        alpha, ll = carry
        p, e = inp
        a = (alpha @ p) * e
        c = jnp.maximum(jnp.sum(a), TINY)
        return (a / c, ll + jnp.log(c)), a / c

    (_, loglik), alphas = lax.scan(
        fwd_step, (a0 / c0, jnp.log(c0)), (pt[1:], et[1:])
    )
    alphas = jnp.concatenate([(a0 / c0)[None], alphas], axis=0)

    def bwd_step(beta, inp):
        p, e = inp
        b = p @ (e * beta)
        c = jnp.maximum(jnp.sum(b), TINY)
        return b / c, b / c

    d = pi.shape[0]
    bT = jnp.ones((d,), dtype=pi.dtype)
    _, betas = lax.scan(bwd_step, bT, (pt[1:], et[1:]), reverse=True)
    betas = jnp.concatenate([betas, bT[None]], axis=0)

    raw = alphas * betas
    gamma = raw / jnp.maximum(jnp.sum(raw, axis=1, keepdims=True), TINY)
    return gamma, loglik


def viterbi(pi, obs, prior, ys, valid):
    """Classical Viterbi (Algorithm 4): forward argmax + backtrace."""
    em = _emissions(obs, ys)
    lpi = _safe_log(pi)
    lem = _safe_log(em)
    d = pi.shape[0]
    idx = jnp.arange(d, dtype=jnp.int32)

    v0 = _safe_log(prior) + lem[0]

    def fwd_step(v, inp):
        le, vld = inp
        scores = v[:, None] + lpi  # (from, to)
        vn = jnp.max(scores, axis=0) + le
        un = jnp.argmax(scores, axis=0).astype(jnp.int32)
        v_out = jnp.where(vld > 0.5, vn, v)
        u_out = jnp.where(vld > 0.5, un, idx)  # identity backtrace on pad
        return v_out, u_out

    v_last, us = lax.scan(fwd_step, v0, (lem[1:], valid[1:]))
    x_last = jnp.argmax(v_last).astype(jnp.int32)

    def back_step(x, u):
        return u[x], u[x]

    _, path_rev = lax.scan(back_step, x_last, us, reverse=True)
    path = jnp.concatenate([path_rev, x_last[None]])
    return path, jnp.max(v_last)


def mp_seq(pi, obs, prior, ys, valid):
    """Sequential max-product (Lemma 3 recursions + Theorem 4 combine)."""
    em = _emissions(obs, ys)
    lpi = _safe_log(pi)
    lem = _safe_log(em)

    f0 = _safe_log(prior) + lem[0]

    def fwd_step(fv, inp):
        le, vld = inp
        fn = jnp.max(fv[:, None] + lpi, axis=0) + le
        f_out = jnp.where(vld > 0.5, fn, fv)
        return f_out, f_out

    _, fs = lax.scan(fwd_step, f0, (lem[1:], valid[1:]))
    fs = jnp.concatenate([f0[None], fs], axis=0)

    d = pi.shape[0]
    bT = jnp.zeros((d,), dtype=pi.dtype)

    def bwd_step(bv, inp):
        le, vld = inp
        bn = jnp.max(lpi + (le + bv)[None, :], axis=1)
        b_out = jnp.where(vld > 0.5, bn, bv)
        return b_out, b_out

    _, bs = lax.scan(bwd_step, bT, (lem[1:], valid[1:]), reverse=True)
    bs = jnp.concatenate([bs, bT[None]], axis=0)

    path = jnp.argmax(fs + bs, axis=1).astype(jnp.int32)  # Eq. (40)
    return path, jnp.max(fs[-1])


def bs_seq(pi, obs, prior, ys, valid):
    """Sequential Bayesian smoother: forward filter + RTS backward pass."""
    em = _emissions(obs, ys)
    pt = _masked_trans(pi, valid)
    et = _masked_emis(em, valid)

    a0 = prior * et[0]
    c0 = jnp.maximum(jnp.sum(a0), TINY)

    def f_step(carry, inp):
        alpha, ll = carry
        p, e = inp
        a = (alpha @ p) * e
        c = jnp.maximum(jnp.sum(a), TINY)
        return (a / c, ll + jnp.log(c)), a / c

    (_, loglik), fs = lax.scan(f_step, (a0 / c0, jnp.log(c0)), (pt[1:], et[1:]))
    fs = jnp.concatenate([(a0 / c0)[None], fs], axis=0)

    def s_step(gnext, inp):
        filt, p = inp
        pred = jnp.maximum(filt @ p, TINY)  # p(x_{t+1} | y_{1:t})
        g = filt * (p @ (gnext / pred))
        g = g / jnp.maximum(jnp.sum(g), TINY)
        return g, g

    _, gammas = lax.scan(s_step, fs[-1], (fs[:-1], pt[1:]), reverse=True)
    gamma = jnp.concatenate([gammas, fs[-1][None]], axis=0)
    return gamma, loglik


# ===========================================================================
# Block-wise entries (paper §V-B) — used by the L3 temporal sharder
# ===========================================================================


def _sp_elements(pi, obs, prior, ys, valid, first):
    em = _emissions(obs, ys)
    mats, logs = ko.sp_element_init(pi, em, valid)
    if first:
        f0m, f0l = ref.first_element_ref(prior, em[0])
        mats = mats.at[0].set(f0m)
        logs = logs.at[0].set(f0l)
    return mats, logs


def _sp_block_fold(pi, obs, prior, ys, valid, first):
    mats, logs = _sp_elements(pi, obs, prior, ys, valid, first)

    def step(carry, elem):
        cm, cl = carry
        m, l = elem
        c = cm @ m
        mx = jnp.maximum(jnp.max(c), TINY)
        return (c / mx, cl + l + jnp.log(mx)), None

    d = pi.shape[0]
    init = (jnp.eye(d, dtype=pi.dtype), jnp.zeros((), pi.dtype))
    (fm, fl), _ = lax.scan(step, init, (mats, logs))
    return fm, fl


def sp_block_fold_first(pi, obs, prior, ys, valid):
    """Fold a leading block into its summary element a_{0:l}."""
    return _sp_block_fold(pi, obs, prior, ys, valid, True)


def sp_block_fold_mid(pi, obs, prior, ys, valid):
    """Fold an interior block into its summary element a_{s:e}."""
    return _sp_block_fold(pi, obs, prior, ys, valid, False)


def _sp_block_finalize(pi, obs, prior, ys, valid, fin, bin_, first):
    mats, logs = _sp_elements(pi, obs, prior, ys, valid, first)
    pref_m, _ = lax.associative_scan(ko.sp_combine, (mats, logs))

    d = pi.shape[0]
    eye = jnp.eye(d, dtype=pi.dtype)[None]
    suf_elems_m = jnp.concatenate([mats[1:], eye], axis=0)
    suf_elems_l = jnp.concatenate([logs[1:], jnp.zeros((1,), logs.dtype)])
    suf_m, _ = lax.associative_scan(
        _sp_combine_flip, (suf_elems_m, suf_elems_l), reverse=True
    )

    # global fwd[t] = fin ⊗ pref[t];  global bwd[t] = suf[t] ⊗ bin
    gf = jnp.einsum("i,bij->bj", fin[0, :], pref_m)  # row 0 of fin ⊗ pref
    gb = jnp.einsum("bij,j->bi", suf_m, bin_[:, 0])  # col 0 of suf ⊗ bin
    raw = gf * gb
    gamma = raw / jnp.maximum(jnp.sum(raw, axis=1, keepdims=True), TINY)
    return (gamma,)


def sp_block_finalize_first(pi, obs, prior, ys, valid, fin, bin_):
    """Marginals for a leading block given incoming fwd/bwd summaries."""
    return _sp_block_finalize(pi, obs, prior, ys, valid, fin, bin_, True)


def sp_block_finalize_mid(pi, obs, prior, ys, valid, fin, bin_):
    """Marginals for an interior block given incoming fwd/bwd summaries."""
    return _sp_block_finalize(pi, obs, prior, ys, valid, fin, bin_, False)


def _mp_elements(pi, obs, prior, ys, valid, first):
    em = _emissions(obs, ys)
    lpi = _safe_log(pi)
    lem = _safe_log(em)
    elems = ko.mp_element_init(lpi, lem, valid)
    if first:
        elems = elems.at[0].set(ref.mp_first_element_ref(_safe_log(prior), lem[0]))
    return elems


def _mp_block_fold(pi, obs, prior, ys, valid, first):
    elems = _mp_elements(pi, obs, prior, ys, valid, first)

    def step(carry, e):
        c = jnp.max(carry[:, :, None] + e[None, :, :], axis=1)
        return c, None

    d = pi.shape[0]
    init = jnp.where(jnp.eye(d, dtype=bool), 0.0, NEG_INF).astype(pi.dtype)
    out, _ = lax.scan(step, init, elems)
    return (out,)


def mp_block_fold_first(pi, obs, prior, ys, valid):
    """Fold a leading block into its max-product summary (log domain)."""
    return _mp_block_fold(pi, obs, prior, ys, valid, True)


def mp_block_fold_mid(pi, obs, prior, ys, valid):
    """Fold an interior block into its max-product summary (log domain)."""
    return _mp_block_fold(pi, obs, prior, ys, valid, False)


def _mp_block_finalize(pi, obs, prior, ys, valid, fin, bin_, first):
    elems = _mp_elements(pi, obs, prior, ys, valid, first)
    pref = lax.associative_scan(ko.mp_combine, elems)

    d = pi.shape[0]
    logeye = jnp.where(jnp.eye(d, dtype=bool), 0.0, NEG_INF).astype(pi.dtype)
    suf_elems = jnp.concatenate([elems[1:], logeye[None]], axis=0)
    suf = lax.associative_scan(_mp_combine_flip, suf_elems, reverse=True)

    # global fwd[t] = row 0 of (fin ∨ pref[t]); bwd[t] = col 0 of (suf[t] ∨ bin)
    gf = jnp.max(fin[0, :, None] + pref, axis=1)  # (l, D)
    gb = jnp.max(suf + bin_[:, 0][None, None, :], axis=2)  # (l, D)
    path = jnp.argmax(gf + gb, axis=1).astype(jnp.int32)
    return (path,)


def mp_block_finalize_first(pi, obs, prior, ys, valid, fin, bin_):
    """MAP states for a leading block given incoming summaries."""
    return _mp_block_finalize(pi, obs, prior, ys, valid, fin, bin_, True)


def mp_block_finalize_mid(pi, obs, prior, ys, valid, fin, bin_):
    """MAP states for an interior block given incoming summaries."""
    return _mp_block_finalize(pi, obs, prior, ys, valid, fin, bin_, False)


# ---------------------------------------------------------------------------
# Entry registry used by aot.py and the tests
# ---------------------------------------------------------------------------

CORE_ENTRIES = {
    "sp_par": sp_par,
    "mp_par": mp_par,
    "bs_par": bs_par,
    "sp_seq": sp_seq,
    "mp_seq": mp_seq,
    "viterbi": viterbi,
    "bs_seq": bs_seq,
}

BLOCK_FOLD_ENTRIES = {
    "sp_block_fold_first": sp_block_fold_first,
    "sp_block_fold_mid": sp_block_fold_mid,
    "mp_block_fold_first": mp_block_fold_first,
    "mp_block_fold_mid": mp_block_fold_mid,
}

BLOCK_FINALIZE_ENTRIES = {
    "sp_block_finalize_first": sp_block_finalize_first,
    "sp_block_finalize_mid": sp_block_finalize_mid,
    "mp_block_finalize_first": mp_block_finalize_first,
    "mp_block_finalize_mid": mp_block_finalize_mid,
}

"""AOT pipeline tests: manifest correctness and HLO round-trip.

The HLO text must (a) parse back into an XlaComputation, (b) execute on
the CPU PJRT client with the manifest's declared signature, and (c) agree
with the jitted L2 function — this is the python half of the L2→L3
interchange contract (the rust half is tested in rust/src/runtime/).
"""

import json
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from .conftest import gilbert_elliott, sample_hmm


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        check=True,
    )
    return out


def test_manifest_schema(quick_artifacts):
    man = json.loads((quick_artifacts / "manifest.json").read_text())
    assert man["version"] == 1
    assert man["interchange"] == "hlo-text"
    names = set()
    for rec in man["artifacts"]:
        assert rec["name"] not in names, "duplicate artifact name"
        names.add(rec["name"])
        assert (quick_artifacts / rec["path"]).exists()
        assert rec["kind"] in ("core", "block")
        assert rec["entry"] in {
            **model.CORE_ENTRIES,
            **model.BLOCK_FOLD_ENTRIES,
            **model.BLOCK_FINALIZE_ENTRIES,
        }
        for io in rec["inputs"] + rec["outputs"]:
            assert io["dtype"] in ("f32", "i32")
            assert all(isinstance(s, int) for s in io["shape"])
    # every core entry present
    core = {r["entry"] for r in man["artifacts"] if r["kind"] == "core"}
    assert core == set(model.CORE_ENTRIES)


def test_hlo_text_reparses(quick_artifacts):
    man = json.loads((quick_artifacts / "manifest.json").read_text())
    rec = next(r for r in man["artifacts"] if r["entry"] == "sp_par")
    text = (quick_artifacts / rec["path"]).read_text()
    assert text.startswith("HloModule")
    # No Mosaic custom-calls may leak into the artifact (CPU PJRT cannot
    # run them — interpret=True must hold everywhere).
    assert "custom-call" not in text.lower()


@pytest.mark.parametrize("entry", ["sp_par", "mp_par", "viterbi", "bs_par"])
def test_artifact_text_deterministic_and_signature(quick_artifacts, entry, rng):
    """The stored HLO text must be exactly re-derivable from the L2 entry
    (the rust side caches compiled executables keyed by the artifact name,
    so nondeterministic lowering would silently invalidate the cache), and
    the jitted entry's outputs must match the manifest signature.

    Actual execution of the text by the PJRT C API is covered on the rust
    side (rust/src/runtime tests) — the python jaxlib client API is not
    the interface the system uses at runtime.
    """
    man = json.loads((quick_artifacts / "manifest.json").read_text())
    rec = next(r for r in man["artifacts"] if r["entry"] == entry)
    t = rec["t"]

    inputs = [aot.spec_of(i) for i in rec["inputs"]]
    text = aot.to_hlo_text(jax.jit(model.CORE_ENTRIES[entry]).lower(*inputs))
    assert text == (quick_artifacts / rec["path"]).read_text()

    pi, obs, prior = gilbert_elliott()
    _, ys = sample_hmm(rng, pi, obs, prior, t)
    valid = np.ones(t, dtype=np.float32)
    out = jax.jit(model.CORE_ENTRIES[entry])(
        jnp.asarray(pi),
        jnp.asarray(obs),
        jnp.asarray(prior),
        jnp.asarray(ys, dtype=jnp.int32),
        jnp.asarray(valid),
    )
    assert len(out) == len(rec["outputs"])
    for got, io in zip(out, rec["outputs"]):
        got = np.asarray(got)
        assert list(got.shape) == io["shape"]
        assert {"f32": np.float32, "i32": np.int32}[io["dtype"]] == got.dtype
        assert np.isfinite(got.astype(np.float64)).all()

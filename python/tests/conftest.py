"""Shared fixtures/helpers for the python test suite."""

import numpy as np
import pytest


def random_hmm(rng, d, m):
    """Random well-conditioned HMM: row-stochastic Π, emission O, prior."""
    pi = rng.uniform(0.05, 1.0, size=(d, d))
    pi /= pi.sum(axis=1, keepdims=True)
    obs = rng.uniform(0.05, 1.0, size=(d, m))
    obs /= obs.sum(axis=1, keepdims=True)
    prior = rng.uniform(0.05, 1.0, size=d)
    prior /= prior.sum()
    return pi.astype(np.float32), obs.astype(np.float32), prior.astype(np.float32)


def gilbert_elliott(p0=0.03, p1=0.1, p2=0.05, q0=0.01, q1=0.1):
    """The paper's Gilbert–Elliott channel model (Eq. 43). D=4, M=2."""
    pi = np.array(
        [
            [(1 - p0) * (1 - p2), p0 * (1 - p2), (1 - p0) * p2, p0 * p2],
            [p1 * (1 - p2), (1 - p1) * (1 - p2), p1 * p2, (1 - p1) * p2],
            [(1 - p0) * p2, p0 * p2, (1 - p0) * (1 - p2), p0 * (1 - p2)],
            [p1 * p2, (1 - p1) * p2, p1 * (1 - p2), (1 - p1) * (1 - p2)],
        ],
        dtype=np.float32,
    )
    obs = np.array(
        [[1 - q0, q0], [1 - q1, q1], [q0, 1 - q0], [q1, 1 - q1]],
        dtype=np.float32,
    )
    prior = np.full(4, 0.25, dtype=np.float32)
    return pi, obs, prior


def sample_hmm(rng, pi, obs, prior, t_len):
    """Ancestral sampling of (states, observations) from an HMM."""
    d, m = obs.shape
    xs = np.empty(t_len, dtype=np.int64)
    ys = np.empty(t_len, dtype=np.int32)
    xs[0] = rng.choice(d, p=prior / prior.sum())
    ys[0] = rng.choice(m, p=obs[xs[0]] / obs[xs[0]].sum())
    for t in range(1, t_len):
        xs[t] = rng.choice(d, p=pi[xs[t - 1]] / pi[xs[t - 1]].sum())
        ys[t] = rng.choice(m, p=obs[xs[t]] / obs[xs[t]].sum())
    return xs, ys


def brute_force_marginals(pi, obs, prior, ys):
    """Enumerate all D^T state sequences; exact smoothing marginals + logZ."""
    t_len = len(ys)
    d = pi.shape[0]
    pi64, obs64, prior64 = pi.astype(np.float64), obs.astype(np.float64), prior.astype(np.float64)
    marg = np.zeros((t_len, d))
    z = 0.0
    for seq in np.ndindex(*([d] * t_len)):
        p = prior64[seq[0]] * obs64[seq[0], ys[0]]
        for t in range(1, t_len):
            p *= pi64[seq[t - 1], seq[t]] * obs64[seq[t], ys[t]]
        z += p
        for t in range(t_len):
            marg[t, seq[t]] += p
    return marg / z, np.log(z)


def brute_force_map(pi, obs, prior, ys):
    """Enumerate all D^T state sequences; exact MAP path + log-probability."""
    t_len = len(ys)
    d = pi.shape[0]
    pi64, obs64, prior64 = pi.astype(np.float64), obs.astype(np.float64), prior.astype(np.float64)
    best, best_seq = -np.inf, None
    for seq in np.ndindex(*([d] * t_len)):
        p = np.log(prior64[seq[0]] * obs64[seq[0], ys[0]])
        for t in range(1, t_len):
            p += np.log(pi64[seq[t - 1], seq[t]] * obs64[seq[t], ys[t]])
        if p > best:
            best, best_seq = p, np.array(seq, dtype=np.int32)
    return best_seq, best


def maxprod_delta_f64(pi, obs, prior, ys):
    """Float64 oracle of δ_k(x) = ψ̃^f_k(x) + ψ̃^b_k(x) (paper Eq. 40).

    Used to make MAP-path comparisons tie-aware: where the MAP estimate is
    non-unique (δ has tied maxima — the paper assumes this away in §IV-A),
    the per-step argmax of Eq. (40) and the Viterbi backtrace may validly
    disagree.
    """
    lpi = np.log(pi.astype(np.float64))
    lem = np.log(obs.astype(np.float64))[:, ys].T
    t_len, d = len(ys), pi.shape[0]
    f = np.empty((t_len, d))
    b = np.empty((t_len, d))
    f[0] = np.log(prior.astype(np.float64)) + lem[0]
    for t in range(1, t_len):
        f[t] = (f[t - 1][:, None] + lpi).max(axis=0) + lem[t]
    b[t_len - 1] = 0.0
    for t in range(t_len - 2, -1, -1):
        b[t] = (lpi + (lem[t + 1] + b[t + 1])[None, :]).max(axis=1)
    return f + b


def assert_map_equivalent(pi, obs, prior, ys, path, ref_path, tol=1e-6):
    """Paths must agree except where δ_k has (near-)tied maxima, and every
    chosen state must attain the per-step maximum of δ_k."""
    path = np.asarray(path)
    ref_path = np.asarray(ref_path)
    delta = maxprod_delta_f64(pi, obs, prior, ys)
    dmax = delta.max(axis=1)
    np.testing.assert_allclose(delta[np.arange(len(ys)), path], dmax, atol=tol)
    diff = np.nonzero(path != ref_path)[0]
    for k in diff:
        top2 = np.sort(delta[k])[::-1]
        assert top2[0] - top2[1] < tol, f"non-tied mismatch at {k}"


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)

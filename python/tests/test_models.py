"""L2 model tests: parallel vs sequential vs brute-force equivalence.

The paper's premise (§VI): parallel and sequential methods are
algebraically equivalent, so error performance is identical — here we
assert it numerically. Small-T cases are additionally checked against an
exact exponential-enumeration oracle.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from .conftest import (
    assert_map_equivalent,
    brute_force_map,
    brute_force_marginals,
    gilbert_elliott,
    random_hmm,
    sample_hmm,
)


def run(entry, pi, obs, prior, ys, valid=None):
    t_len = len(ys)
    if valid is None:
        valid = np.ones(t_len, dtype=np.float32)
    return jax.jit(M.CORE_ENTRIES[entry])(
        jnp.asarray(pi),
        jnp.asarray(obs),
        jnp.asarray(prior),
        jnp.asarray(ys, dtype=jnp.int32),
        jnp.asarray(valid),
    )


# ---------------------------------------------------------------------------
# Exact oracle (small T)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    d=st.sampled_from([2, 3]),
    m=st.sampled_from([2, 3]),
    t=st.sampled_from([1, 2, 5, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_smoothers_match_brute_force(d, m, t, seed):
    rng = np.random.default_rng(seed)
    pi, obs, prior = random_hmm(rng, d, m)
    ys = rng.integers(0, m, size=t).astype(np.int32)
    exact, logz = brute_force_marginals(pi, obs, prior, ys)
    for entry in ("sp_par", "sp_seq", "bs_par", "bs_seq"):
        gamma, loglik = run(entry, pi, obs, prior, ys)
        np.testing.assert_allclose(
            np.asarray(gamma), exact, rtol=2e-4, atol=2e-5, err_msg=entry
        )
        assert float(loglik) == pytest.approx(logz, rel=2e-4), entry


@settings(max_examples=8, deadline=None)
@given(
    d=st.sampled_from([2, 3]),
    t=st.sampled_from([1, 2, 5, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_map_matches_brute_force(d, t, seed):
    rng = np.random.default_rng(seed)
    pi, obs, prior = random_hmm(rng, d, 2)
    ys = rng.integers(0, 2, size=t).astype(np.int32)
    exact_path, exact_logp = brute_force_map(pi, obs, prior, ys)
    for entry in ("mp_par", "mp_seq", "viterbi"):
        path, logp = run(entry, pi, obs, prior, ys)
        assert float(logp) == pytest.approx(exact_logp, rel=2e-4), entry
        np.testing.assert_array_equal(np.asarray(path), exact_path, err_msg=entry)


# ---------------------------------------------------------------------------
# Par vs Seq equivalence at realistic lengths (GE model, paper §VI)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t_len", [64, 100, 256, 1000])
def test_parallel_equals_sequential_ge(t_len, rng):
    pi, obs, prior = gilbert_elliott()
    _, ys = sample_hmm(rng, pi, obs, prior, t_len)

    g_par, ll_par = run("sp_par", pi, obs, prior, ys)
    g_seq, ll_seq = run("sp_seq", pi, obs, prior, ys)
    g_bsp, ll_bsp = run("bs_par", pi, obs, prior, ys)
    g_bss, ll_bss = run("bs_seq", pi, obs, prior, ys)
    np.testing.assert_allclose(g_par, g_seq, atol=2e-5)
    np.testing.assert_allclose(g_bsp, g_seq, atol=2e-5)
    np.testing.assert_allclose(g_bss, g_seq, atol=2e-5)
    assert float(ll_par) == pytest.approx(float(ll_seq), rel=1e-5)
    assert float(ll_bsp) == pytest.approx(float(ll_seq), rel=1e-5)
    assert float(ll_bss) == pytest.approx(float(ll_seq), rel=1e-5)

    # The GE model develops exactly-tied MAP paths at long T (the paper's
    # §IV-A uniqueness assumption fails), so the comparison is tie-aware.
    p_mp, lp_mp = run("mp_par", pi, obs, prior, ys)
    p_ms, lp_ms = run("mp_seq", pi, obs, prior, ys)
    p_vit, lp_vit = run("viterbi", pi, obs, prior, ys)
    assert float(lp_mp) == pytest.approx(float(lp_vit), rel=1e-5)
    assert float(lp_ms) == pytest.approx(float(lp_vit), rel=1e-5)
    assert_map_equivalent(pi, obs, prior, ys, p_mp, p_vit, tol=1e-4)
    assert_map_equivalent(pi, obs, prior, ys, p_ms, p_vit, tol=1e-4)


@settings(max_examples=6, deadline=None)
@given(
    d=st.sampled_from([2, 4, 8]),
    m=st.sampled_from([2, 5]),
    t=st.sampled_from([33, 64, 129]),
    seed=st.integers(0, 2**31 - 1),
)
def test_parallel_equals_sequential_random(d, m, t, seed):
    rng = np.random.default_rng(seed)
    pi, obs, prior = random_hmm(rng, d, m)
    ys = rng.integers(0, m, size=t).astype(np.int32)
    g_par, ll_par = run("sp_par", pi, obs, prior, ys)
    g_seq, ll_seq = run("sp_seq", pi, obs, prior, ys)
    np.testing.assert_allclose(g_par, g_seq, atol=3e-5)
    assert float(ll_par) == pytest.approx(float(ll_seq), rel=1e-4)
    p_mp, lp_mp = run("mp_par", pi, obs, prior, ys)
    p_vit, lp_vit = run("viterbi", pi, obs, prior, ys)
    assert float(lp_mp) == pytest.approx(float(lp_vit), rel=1e-4)
    assert_map_equivalent(pi, obs, prior, ys, p_mp, p_vit, tol=1e-3)


# ---------------------------------------------------------------------------
# Padding mask: artifact of length T serves any V ≤ T
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("entry", ["sp_par", "sp_seq", "bs_par", "bs_seq"])
def test_padding_mask_smoothers(entry, rng):
    pi, obs, prior = gilbert_elliott()
    v_len, t_len = 77, 128
    _, ys = sample_hmm(rng, pi, obs, prior, v_len)
    ys_pad = np.concatenate([ys, np.zeros(t_len - v_len, dtype=np.int32)])
    valid = np.concatenate(
        [np.ones(v_len, dtype=np.float32), np.zeros(t_len - v_len, dtype=np.float32)]
    )
    g_pad, ll_pad = run(entry, pi, obs, prior, ys_pad, valid)
    g_ref, ll_ref = run("sp_seq", pi, obs, prior, ys)
    np.testing.assert_allclose(np.asarray(g_pad)[:v_len], g_ref, atol=3e-5)
    assert float(ll_pad) == pytest.approx(float(ll_ref), rel=1e-5)


@pytest.mark.parametrize("entry", ["mp_par", "mp_seq", "viterbi"])
def test_padding_mask_map(entry, rng):
    pi, obs, prior = gilbert_elliott()
    v_len, t_len = 50, 64
    _, ys = sample_hmm(rng, pi, obs, prior, v_len)
    ys_pad = np.concatenate([ys, np.zeros(t_len - v_len, dtype=np.int32)])
    valid = np.concatenate(
        [np.ones(v_len, dtype=np.float32), np.zeros(t_len - v_len, dtype=np.float32)]
    )
    p_pad, lp_pad = run(entry, pi, obs, prior, ys_pad, valid)
    p_ref, lp_ref = run("viterbi", pi, obs, prior, ys)
    assert float(lp_pad) == pytest.approx(float(lp_ref), rel=1e-5)
    assert_map_equivalent(
        pi, obs, prior, ys, np.asarray(p_pad)[:v_len], p_ref, tol=1e-4
    )


# ---------------------------------------------------------------------------
# Block-wise entries (§V-B): two-level scan ≡ flat scan
# ---------------------------------------------------------------------------


def np32(x):
    return jnp.asarray(np.asarray(x, dtype=np.float32))


def test_sp_blockwise_matches_flat(rng):
    pi, obs, prior = gilbert_elliott()
    t_len, block = 256, 64
    _, ys = sample_hmm(rng, pi, obs, prior, t_len)
    valid = np.ones(block, dtype=np.float32)
    nb = t_len // block

    # Phase 1: per-block folds.
    folds = []
    for b in range(nb):
        fn = M.sp_block_fold_first if b == 0 else M.sp_block_fold_mid
        fm, fl = jax.jit(fn)(
            np32(pi), np32(obs), np32(prior),
            jnp.asarray(ys[b * block : (b + 1) * block], jnp.int32),
            np32(valid),
        )
        folds.append((np.asarray(fm, dtype=np.float64), float(fl)))

    # Leader combines (done natively in Rust in the real system).
    def comb(a, b):
        c = a[0] @ b[0]
        mx = c.max()
        return c / mx, a[1] + b[1] + np.log(mx)

    d = pi.shape[0]
    ident = (np.eye(d), 0.0)
    ones = (np.ones((d, d)), 0.0)
    prefixes, suffixes = [], [None] * nb
    acc = ident
    for b in range(nb):
        prefixes.append(acc)
        acc = comb(acc, folds[b])
    acc = ones  # a_{T:T+1} terminal fold
    for b in reversed(range(nb)):
        suffixes[b] = acc
        acc = comb(folds[b], acc)

    # Phase 2: per-block finalize.
    gammas = []
    for b in range(nb):
        fn = M.sp_block_finalize_first if b == 0 else M.sp_block_finalize_mid
        (g,) = jax.jit(fn)(
            np32(pi), np32(obs), np32(prior),
            jnp.asarray(ys[b * block : (b + 1) * block], jnp.int32),
            np32(valid),
            np32(prefixes[b][0]), np32(suffixes[b][0]),
        )
        gammas.append(np.asarray(g))

    g_flat, _ = run("sp_seq", pi, obs, prior, ys)
    np.testing.assert_allclose(np.concatenate(gammas), g_flat, atol=5e-5)


def test_mp_blockwise_matches_flat(rng):
    pi, obs, prior = gilbert_elliott()
    t_len, block = 256, 64
    _, ys = sample_hmm(rng, pi, obs, prior, t_len)
    valid = np.ones(block, dtype=np.float32)
    nb = t_len // block

    folds = []
    for b in range(nb):
        fn = M.mp_block_fold_first if b == 0 else M.mp_block_fold_mid
        (fm,) = jax.jit(fn)(
            np32(pi), np32(obs), np32(prior),
            jnp.asarray(ys[b * block : (b + 1) * block], jnp.int32),
            np32(valid),
        )
        folds.append(np.asarray(fm, dtype=np.float64))

    def comb(a, b):
        return (a[:, :, None] + b[None, :, :]).max(axis=1)

    d = pi.shape[0]
    ident = np.where(np.eye(d, dtype=bool), 0.0, M.NEG_INF)
    prefixes, suffixes = [], [None] * nb
    acc = ident
    for b in range(nb):
        prefixes.append(acc)
        acc = comb(acc, folds[b])
    acc = np.zeros((d, d))  # terminal: ψ_{T,T+1}=1 → log 0
    for b in reversed(range(nb)):
        suffixes[b] = acc
        acc = comb(folds[b], acc)

    paths = []
    for b in range(nb):
        fn = M.mp_block_finalize_first if b == 0 else M.mp_block_finalize_mid
        (p,) = jax.jit(fn)(
            np32(pi), np32(obs), np32(prior),
            jnp.asarray(ys[b * block : (b + 1) * block], jnp.int32),
            np32(valid),
            np32(prefixes[b]), np32(suffixes[b]),
        )
        paths.append(np.asarray(p))

    p_flat, _ = run("viterbi", pi, obs, prior, ys)
    assert_map_equivalent(pi, obs, prior, ys, np.concatenate(paths), p_flat, tol=1e-4)

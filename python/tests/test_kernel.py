"""L1 kernel tests: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and values; the kernels must agree with the
reference to float32 tolerance, and the combine operators must satisfy the
paper's algebraic requirements (associativity — Lemmas 1 and 2 — and the
identity element used for padding).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import assoc_ops as ko
from compile.kernels import ref


def sp_elem(rng, b, d):
    m = rng.uniform(0.05, 1.0, size=(b, d, d)).astype(np.float32)
    m /= m.max(axis=(1, 2), keepdims=True)
    s = rng.uniform(-3.0, 3.0, size=b).astype(np.float32)
    return jnp.asarray(m), jnp.asarray(s)


def mp_elem(rng, b, d):
    return jnp.asarray(rng.uniform(-5.0, 0.0, size=(b, d, d)).astype(np.float32))


# ---------------------------------------------------------------------------
# Pallas vs reference
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 3, 64, 65, 130]),
    d=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sp_combine_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    a = sp_elem(rng, b, d)
    c = sp_elem(rng, b, d)
    km, kl = ko.sp_combine(a, c)
    rm, rl = ref.sp_combine_ref(a[0], a[1], c[0], c[1])
    np.testing.assert_allclose(km, rm, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(kl, rl, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 64, 100, 129]),
    d=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mp_combine_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    a = mp_elem(rng, b, d)
    c = mp_elem(rng, b, d)
    np.testing.assert_allclose(
        ko.mp_combine(a, c), ref.mp_combine_ref(a, c), rtol=1e-6, atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([1, 7, 256, 300]),
    d=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sp_element_init_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    pi = rng.uniform(0.01, 1.0, size=(d, d)).astype(np.float32)
    pi /= pi.sum(axis=1, keepdims=True)
    em = rng.uniform(0.01, 1.0, size=(t, d)).astype(np.float32)
    valid = (rng.uniform(size=t) > 0.3).astype(np.float32)
    km, kl = ko.sp_element_init(jnp.asarray(pi), jnp.asarray(em), jnp.asarray(valid))
    rm, rl = ref.sp_element_init_ref(jnp.asarray(pi), jnp.asarray(em), jnp.asarray(valid))
    np.testing.assert_allclose(km, rm, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(kl, rl, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([1, 5, 256, 257]),
    d=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mp_element_init_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    lpi = rng.uniform(-4.0, 0.0, size=(d, d)).astype(np.float32)
    lem = rng.uniform(-4.0, 0.0, size=(t, d)).astype(np.float32)
    valid = (rng.uniform(size=t) > 0.3).astype(np.float32)
    k = ko.mp_element_init(jnp.asarray(lpi), jnp.asarray(lem), jnp.asarray(valid))
    r = ref.mp_element_init_ref(jnp.asarray(lpi), jnp.asarray(lem), jnp.asarray(valid))
    np.testing.assert_allclose(k, r, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Algebraic laws (Lemma 1 / Lemma 2)
# ---------------------------------------------------------------------------


def rep_sp(m, s):
    """Represented (unscaled) potential matrices of an SP element batch."""
    return np.asarray(m) * np.exp(np.asarray(s))[:, None, None]


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_sp_combine_associative(d, seed):
    rng = np.random.default_rng(seed)
    a, b, c = (sp_elem(rng, 4, d) for _ in range(3))
    left = ko.sp_combine(ko.sp_combine(a, b), c)
    right = ko.sp_combine(a, ko.sp_combine(b, c))
    np.testing.assert_allclose(
        rep_sp(*left), rep_sp(*right), rtol=1e-4, atol=1e-6
    )


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_mp_combine_associative(d, seed):
    rng = np.random.default_rng(seed)
    a, b, c = (mp_elem(rng, 4, d) for _ in range(3))
    left = ko.mp_combine(ko.mp_combine(a, b), c)
    right = ko.mp_combine(a, ko.mp_combine(b, c))
    np.testing.assert_allclose(left, right, rtol=1e-5, atol=1e-5)


def test_sp_identity_element():
    """The padding element (I, 0) must be a two-sided identity for ⊗."""
    rng = np.random.default_rng(7)
    d = 4
    a = sp_elem(rng, 3, d)
    eye = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32), (3, d, d))
    zero = jnp.zeros(3, dtype=jnp.float32)
    for m, s in (ko.sp_combine(a, (eye, zero)), ko.sp_combine((eye, zero), a)):
        np.testing.assert_allclose(rep_sp(m, s), rep_sp(*a), rtol=1e-6)


def test_mp_identity_element():
    """The log-domain identity (0 diag, -inf off) is neutral for ∨."""
    rng = np.random.default_rng(8)
    d = 4
    a = mp_elem(rng, 3, d)
    ident = jnp.broadcast_to(
        jnp.where(jnp.eye(d, dtype=bool), 0.0, ref.NEG_INF).astype(jnp.float32),
        (3, d, d),
    )
    np.testing.assert_allclose(ko.mp_combine(a, ident), a, rtol=1e-6)
    np.testing.assert_allclose(ko.mp_combine(ident, a), a, rtol=1e-6)


def test_sp_combine_empty_batch():
    a = (jnp.zeros((0, 4, 4)), jnp.zeros((0,)))
    m, s = ko.sp_combine(a, a)
    assert m.shape == (0, 4, 4) and s.shape == (0,)


def test_mp_combine_empty_batch():
    a = jnp.zeros((0, 4, 4))
    assert ko.mp_combine(a, a).shape == (0, 4, 4)


def test_sp_combine_underflow_resistance():
    """Chained combines at tiny magnitudes must not underflow: the log
    accumulator absorbs the scale (DESIGN.md §2.2)."""
    rng = np.random.default_rng(9)
    d = 4
    m = rng.uniform(0.05, 1.0, size=(1, d, d)).astype(np.float32)
    m /= m.max()
    elem = (jnp.asarray(m), jnp.asarray(np.float32([-80.0])))  # e^-80 scale
    acc = elem
    for _ in range(50):  # raw product scale e^-4000 — far below f32 range
        acc = ko.sp_combine(acc, elem)
    assert np.isfinite(np.asarray(acc[0])).all()
    assert np.asarray(acc[0]).max() == pytest.approx(1.0, rel=1e-5)
    assert np.isfinite(float(acc[1][0]))
    assert float(acc[1][0]) < -4000.0
